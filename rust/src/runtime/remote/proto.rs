//! Dependency-free length-prefixed wire protocol for the remote
//! executor (`DVIR` v5, pipelined: v3 framing + `ForkKv` + `ObsPull`).
//!
//! Every message is one frame: a `u32` little-endian payload length
//! followed by the payload; the payload's first byte is an opcode tag.
//! Tensors travel as raw little-endian bits, so a value that crosses the
//! wire is **bitwise identical** on both sides — the losslessness
//! invariant the scheduler tests assert survives the transport by
//! construction, not by tolerance.
//!
//! ## v3 framing: negotiate untagged, then pipeline by call id
//!
//! The **first** frame each way on a connection is an *untagged*
//! `Hello` / `Hello`-reply pair — its wire layout is shared with v2, so
//! a version mismatch is detected in-band and answered with a clean
//! `Reply::Err` instead of a framing error (mixed v2/v3 fleets are
//! rejected at connect time, not mid-decode). Every frame **after** a
//! successful v3 handshake is tagged: an 8-byte little-endian
//! **call id** ([`tag`] / [`untag`]) precedes the opcode payload.
//! Requests carry ids minted by the client; each reply echoes the id of
//! the request it answers. Ids are what make the connection
//! *multiplexed*: many calls can be in flight at once (bounded by the
//! client's window) and replies are matched to callers by id, so they
//! may legally arrive out of order.
//!
//! The protocol covers exactly the [`crate::runtime::Backend`] seam:
//!
//! * `Hello` — version handshake carrying the client's **session id**
//!   (stable across reconnects of one client; the executor scopes
//!   buffer ownership to it, freeing everything a session owns when its
//!   last connection closes). The reply carries the executor's
//!   **weights fingerprint** (hash of loaded weights + initial globals;
//!   0 = unknown), so a sharded client can reject a fleet whose
//!   executors front divergent weights at connect time instead of
//!   waiting for a train-step drift check. Optionally returns the
//!   executor's manifest/prompts/vocabulary as one JSON document
//!   ([`hello_json`] / [`HelloInfo`]), so a client [`crate::runtime::Runtime`]
//!   can be constructed from nothing but a connection.
//! * `Call` — `call`/`call_batched` unified as a lane list. Per-sequence
//!   KV state stays **server-resident**: lanes reference buffers by id,
//!   and each reply returns fresh ids for the chained KV outputs. A
//!   `frees` list piggybacks dropped client handles on the hot path.
//! * `FreshKv` / `ForkKv` / `Upload` / `Download` — buffer lifecycle +
//!   staging. `ForkKv` (v4) aliases server-resident parent buffers
//!   under new ids owned by the caller's session: the copy-on-write
//!   attach primitive behind the scheduler's prefix cache.
//! * `SetGlobal` / `ReadGlobal` / `ResetGlobal` — mutable globals
//!   (LoRA adapters, Adam moments), so the online learner runs
//!   unmodified against a remote executor.
//! * `Free` — standalone handle release.
//! * `Metrics` — executor-side occupancy counters ([`ExecMetrics`]:
//!   calls/lanes served, buffer-table size, live sessions), so a client
//!   router can expose remote executor health next to its own stats.
//! * `ObsPull` (v5) — fleet trace collection. With `drain: false` it is
//!   a lightweight clock ping: the `ObsDump` reply carries only the
//!   executor's trace-epoch `now_ns`, which the client's offset
//!   estimator midpoints against its own send/receive stamps. With
//!   `drain: true` the reply additionally drains the executor's
//!   trace-event rings (as owned [`OwnedEvent`]s — `exec` spans carry
//!   their call id, the cross-process correlation key) and snapshots
//!   its metrics registry as JSON, so `dvi trace-collect` can merge
//!   per-shard executor timelines with the client trace.

use std::collections::BTreeMap;

use anyhow::{bail, ensure, Context, Result};

use crate::obs::trace::{Arg as TraceArg, OwnedEvent};
use crate::runtime::manifest::Manifest;
use crate::runtime::tensor::{DType, Tensor, TensorData};
use crate::util::json::Json;
use crate::workload::{PromptSample, PromptSet};

/// Protocol version; bumped on any wire-format change.
/// v2: `Hello` carries the client session id; `Metrics` added.
/// v3: pipelined multiplexing — every post-handshake frame is prefixed
/// with a `u64` call id; the `Hello` reply carries the executor's
/// weights fingerprint.
/// v4: `ForkKv` added — copy-on-write aliasing of server-resident KV
/// buffers under the caller's session (prefix-cache attach).
/// v5: `ObsPull` / `ObsDump` added — clock pings and remote drains of
/// the executor's trace rings + metrics snapshot (fleet trace
/// collection).
///
/// The `Hello` request's wire layout is **stable across versions**, so
/// the version check happens in-band: a mismatched peer gets a clean
/// `Reply::Err` naming both versions, before any tagged frame is
/// exchanged. Everything after the handshake is version-specific and
/// never reached by a rejected peer.
pub const VERSION: u32 = 5;

/// Upper bound on a single frame, guarding a corrupted length prefix.
pub const MAX_FRAME: usize = 256 << 20;

/// Prefix `payload` with its call id — the v3 post-handshake framing.
/// (Hot paths use [`Msg::encode_tagged`] / [`Reply::encode_tagged`],
/// which write the id into the same buffer as the payload instead of
/// re-copying an already-encoded frame.)
pub fn tag(call_id: u64, payload: &[u8]) -> Vec<u8> {
    let mut frame = Vec::with_capacity(8 + payload.len());
    frame.extend_from_slice(&call_id.to_le_bytes());
    frame.extend_from_slice(payload);
    frame
}

/// Split a tagged frame into its call id and opcode payload.
pub fn untag(frame: &[u8]) -> Result<(u64, &[u8])> {
    ensure!(
        frame.len() >= 8,
        "tagged frame too short ({} bytes; want >= 8 for the call id)",
        frame.len()
    );
    let id = u64::from_le_bytes(frame[..8].try_into().unwrap());
    Ok((id, &frame[8..]))
}

// Opcode tags (request space < 128, reply space >= 128).
const OP_HELLO: u8 = 1;
const OP_CALL: u8 = 2;
const OP_FRESH_KV: u8 = 3;
const OP_UPLOAD: u8 = 4;
const OP_DOWNLOAD: u8 = 5;
const OP_SET_GLOBAL: u8 = 6;
const OP_READ_GLOBAL: u8 = 7;
const OP_RESET_GLOBAL: u8 = 8;
const OP_FREE: u8 = 9;
const OP_METRICS: u8 = 10;
const OP_FORK_KV: u8 = 11;
const OP_OBS_PULL: u8 = 12;
const RE_HELLO: u8 = 128;
const RE_LANES: u8 = 129;
const RE_BUFFERS: u8 = 130;
const RE_TENSOR: u8 = 131;
const RE_UNIT: u8 = 132;
const RE_ERR: u8 = 133;
const RE_METRICS: u8 = 134;
const RE_OBS_DUMP: u8 = 135;

/// Server-side buffer descriptor: the id plus the host-visible
/// dtype/shape the client needs to rehydrate a handle.
#[derive(Debug, Clone, PartialEq)]
pub struct BufInfo {
    pub id: u64,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

/// One independent sequence's slice of a batched call.
#[derive(Debug, Clone, PartialEq)]
pub struct Lane {
    /// Server-resident KV buffer ids, in manifest kv-param order.
    pub kv: Vec<u64>,
    /// Per-call host inputs, in manifest in-param order.
    pub inputs: Vec<Tensor>,
}

/// One lane's result: host outputs inline, chained KV as fresh ids.
#[derive(Debug, Clone, PartialEq)]
pub struct LaneOut {
    pub outputs: Vec<Tensor>,
    pub kv: Vec<BufInfo>,
}

/// The wire `Metrics` reply carries the transport-neutral
/// [`ExecMetrics`] defined at the backend seam; re-exported here so
/// protocol users can name it next to [`Msg`]/[`Reply`].
pub use crate::runtime::backend::ExecMetrics;

/// Client → server messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Msg {
    Hello { version: u32, want_manifest: bool, session: u64 },
    Call { artifact: String, frees: Vec<u64>, lanes: Vec<Lane> },
    FreshKv { artifact: String },
    /// Copy-on-write fork: alias each parent buffer under a new id
    /// owned by the caller's session. Buffers are immutable once
    /// written (every call returns *fresh* output KV ids), so aliasing
    /// the storage is bitwise-safe; the fork exists to give the child
    /// an independent lifetime/refcount. The client supplies dtype and
    /// shape from its own handles so the reply can mint new handles
    /// without a server-side lookup of host metadata.
    ForkKv { parents: Vec<BufInfo> },
    Upload { tensor: Tensor },
    Download { id: u64, dtype: DType, shape: Vec<usize> },
    SetGlobal { name: String, tensor: Tensor },
    ReadGlobal { name: String },
    ResetGlobal { name: String },
    Free { ids: Vec<u64> },
    Metrics,
    /// Fleet trace collection (v5). `drain: false` is a clock ping —
    /// the reply carries only the executor's trace-epoch `now_ns`.
    /// `drain: true` additionally collect-and-clears the executor's
    /// trace rings and snapshots its metrics registry.
    ObsPull { drain: bool },
}

/// Server → client messages.
#[derive(Debug, Clone, PartialEq)]
pub enum Reply {
    /// Handshake reply. `weights_hash` fingerprints the executor's
    /// loaded weights + initial globals (0 = backend cannot hash); the
    /// sharded client rejects fleets whose fingerprints differ.
    Hello { backend: String, manifest_json: Option<String>, weights_hash: u64 },
    Lanes(Vec<LaneOut>),
    Buffers(Vec<BufInfo>),
    Tensor(Tensor),
    Unit,
    Err(String),
    Metrics(ExecMetrics),
    /// Reply to [`Msg::ObsPull`]. `now_ns` is the executor's
    /// trace-epoch clock at execution time (the offset estimator's
    /// server stamp). For drains, `events` holds the collected trace
    /// events (empty for clock pings), `dropped` the executor's
    /// ring-overflow total, and `metrics_json` its registry snapshot
    /// (empty string for pings).
    ObsDump {
        now_ns: u64,
        dropped: u64,
        events: Vec<OwnedEvent>,
        metrics_json: String,
    },
}

// ----------------------------------------------------------------------------
// Primitive codec
// ----------------------------------------------------------------------------

#[derive(Default)]
struct Enc(Vec<u8>);

impl Enc {
    fn u8(&mut self, v: u8) {
        self.0.push(v);
    }

    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }

    fn str(&mut self, s: &str) {
        self.u32(s.len() as u32);
        self.0.extend_from_slice(s.as_bytes());
    }

    fn ids(&mut self, ids: &[u64]) {
        self.u32(ids.len() as u32);
        for &id in ids {
            self.u64(id);
        }
    }

    fn shape(&mut self, shape: &[usize]) {
        self.u8(shape.len() as u8);
        for &d in shape {
            self.u64(d as u64);
        }
    }

    fn tensor(&mut self, t: &Tensor) {
        self.u8(dtype_code(t.dtype()));
        self.shape(&t.shape);
        match &t.data {
            TensorData::F32(v) => {
                for x in v {
                    self.0.extend_from_slice(&x.to_le_bytes());
                }
            }
            TensorData::I32(v) => {
                for x in v {
                    self.0.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
    }

    fn tensors(&mut self, ts: &[Tensor]) {
        self.u32(ts.len() as u32);
        for t in ts {
            self.tensor(t);
        }
    }

    fn buf_info(&mut self, b: &BufInfo) {
        self.u64(b.id);
        self.u8(dtype_code(b.dtype));
        self.shape(&b.shape);
    }

    fn buf_infos(&mut self, bs: &[BufInfo]) {
        self.u32(bs.len() as u32);
        for b in bs {
            self.buf_info(b);
        }
    }

    fn trace_arg(&mut self, v: &TraceArg) {
        match v {
            TraceArg::I(n) => {
                self.u8(0);
                self.u64(*n as u64);
            }
            TraceArg::F(f) => {
                self.u8(1);
                self.u64(f.to_bits());
            }
            TraceArg::S(s) => {
                self.u8(2);
                self.str(s);
            }
        }
    }

    fn owned_event(&mut self, ev: &OwnedEvent) {
        self.str(&ev.name);
        self.str(&ev.cat);
        self.u8(ev.ph as u8);
        self.u64(ev.ts_ns as u64);
        self.u64(ev.dur_ns);
        self.u64(ev.tid);
        self.u32(ev.args.len() as u32);
        for (k, v) in &ev.args {
            self.str(k);
            self.trace_arg(v);
        }
    }

    fn owned_events(&mut self, evs: &[OwnedEvent]) {
        self.u32(evs.len() as u32);
        for ev in evs {
            self.owned_event(ev);
        }
    }
}

fn dtype_code(d: DType) -> u8 {
    match d {
        DType::F32 => 0,
        DType::I32 => 1,
    }
}

struct Dec<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Dec<'a> {
    fn new(b: &'a [u8]) -> Dec<'a> {
        Dec { b, i: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            self.i + n <= self.b.len(),
            "truncated frame at byte {} (wanted {n} more of {})",
            self.i,
            self.b.len()
        );
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Bounded collection length: every element of the collection
    /// occupies at least `min_elem` payload bytes, so a count whose
    /// minimum encoding exceeds the remaining bytes is corrupt —
    /// rejected here, before any count-sized work happens.
    fn len(&mut self, min_elem: usize) -> Result<usize> {
        let n = self.u32()? as usize;
        let need = n.checked_mul(min_elem).context("collection size overflow")?;
        ensure!(
            need <= self.b.len() - self.i,
            "implausible collection length {n}"
        );
        Ok(n)
    }

    fn str(&mut self) -> Result<String> {
        let n = self.len(1)?;
        let s = self.take(n)?;
        Ok(std::str::from_utf8(s).context("non-utf8 string")?.to_string())
    }

    fn ids(&mut self) -> Result<Vec<u64>> {
        let n = self.len(8)?;
        (0..n).map(|_| self.u64()).collect()
    }

    fn shape(&mut self) -> Result<Vec<usize>> {
        let n = self.u8()? as usize;
        (0..n).map(|_| Ok(self.u64()? as usize)).collect()
    }

    fn tensor(&mut self) -> Result<Tensor> {
        let dtype = DType::from_code(self.u8()?)?;
        let shape = self.shape()?;
        let n = shape
            .iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d))
            .context("tensor shape overflow")?;
        let bytes = n.checked_mul(4).context("tensor size overflow")?;
        let raw = self.take(bytes)?;
        Ok(match dtype {
            DType::F32 => Tensor::f32(
                shape,
                raw.chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            ),
            DType::I32 => Tensor::i32(
                shape,
                raw.chunks_exact(4)
                    .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            ),
        })
    }

    fn tensors(&mut self) -> Result<Vec<Tensor>> {
        // dtype byte + ndim byte is the smallest possible tensor.
        let n = self.len(2)?;
        (0..n).map(|_| self.tensor()).collect()
    }

    fn buf_info(&mut self) -> Result<BufInfo> {
        Ok(BufInfo {
            id: self.u64()?,
            dtype: DType::from_code(self.u8()?)?,
            shape: self.shape()?,
        })
    }

    fn buf_infos(&mut self) -> Result<Vec<BufInfo>> {
        // id (8) + dtype (1) + ndim (1) is the smallest buffer info.
        let n = self.len(10)?;
        (0..n).map(|_| self.buf_info()).collect()
    }

    fn trace_arg(&mut self) -> Result<TraceArg> {
        Ok(match self.u8()? {
            0 => TraceArg::I(self.u64()? as i64),
            1 => TraceArg::F(f64::from_bits(self.u64()?)),
            2 => TraceArg::S(self.str()?),
            code => bail!("unknown trace-arg code {code}"),
        })
    }

    fn owned_event(&mut self) -> Result<OwnedEvent> {
        let name = self.str()?;
        let cat = self.str()?;
        let ph = self.u8()? as char;
        let ts_ns = self.u64()? as i64;
        let dur_ns = self.u64()?;
        let tid = self.u64()?;
        // key len (4) + value tag (1) is the smallest argument.
        let n = self.len(5)?;
        let args = (0..n)
            .map(|_| Ok((self.str()?, self.trace_arg()?)))
            .collect::<Result<_>>()?;
        Ok(OwnedEvent { name, cat, ph, ts_ns, dur_ns, tid, args })
    }

    fn owned_events(&mut self) -> Result<Vec<OwnedEvent>> {
        // name len (4) + cat len (4) + ph (1) + ts (8) + dur (8) +
        // tid (8) + args count (4) is the smallest event.
        let n = self.len(37)?;
        (0..n).map(|_| self.owned_event()).collect()
    }

    fn finish(self) -> Result<()> {
        ensure!(
            self.i == self.b.len(),
            "trailing bytes in frame ({} of {})",
            self.b.len() - self.i,
            self.b.len()
        );
        Ok(())
    }
}

// ----------------------------------------------------------------------------
// Message codec
// ----------------------------------------------------------------------------

impl Msg {
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc::default();
        self.encode_body(&mut e);
        e.0
    }

    /// Encode with the v3 call-id prefix written into the same buffer
    /// — one allocation, no re-copy of the payload (tensors can be
    /// large; this is the per-request hot path).
    pub fn encode_tagged(&self, call_id: u64) -> Vec<u8> {
        let mut e = Enc::default();
        e.u64(call_id);
        self.encode_body(&mut e);
        e.0
    }

    fn encode_body(&self, e: &mut Enc) {
        match self {
            Msg::Hello { version, want_manifest, session } => {
                e.u8(OP_HELLO);
                e.u32(*version);
                e.u8(*want_manifest as u8);
                e.u64(*session);
            }
            Msg::Call { artifact, frees, lanes } => {
                e.u8(OP_CALL);
                e.str(artifact);
                e.ids(frees);
                e.u32(lanes.len() as u32);
                for lane in lanes {
                    e.ids(&lane.kv);
                    e.tensors(&lane.inputs);
                }
            }
            Msg::FreshKv { artifact } => {
                e.u8(OP_FRESH_KV);
                e.str(artifact);
            }
            Msg::ForkKv { parents } => {
                e.u8(OP_FORK_KV);
                e.buf_infos(parents);
            }
            Msg::Upload { tensor } => {
                e.u8(OP_UPLOAD);
                e.tensor(tensor);
            }
            Msg::Download { id, dtype, shape } => {
                e.u8(OP_DOWNLOAD);
                e.u64(*id);
                e.u8(dtype_code(*dtype));
                e.shape(shape);
            }
            Msg::SetGlobal { name, tensor } => {
                e.u8(OP_SET_GLOBAL);
                e.str(name);
                e.tensor(tensor);
            }
            Msg::ReadGlobal { name } => {
                e.u8(OP_READ_GLOBAL);
                e.str(name);
            }
            Msg::ResetGlobal { name } => {
                e.u8(OP_RESET_GLOBAL);
                e.str(name);
            }
            Msg::Free { ids } => {
                e.u8(OP_FREE);
                e.ids(ids);
            }
            Msg::Metrics => e.u8(OP_METRICS),
            Msg::ObsPull { drain } => {
                e.u8(OP_OBS_PULL);
                e.u8(*drain as u8);
            }
        }
    }

    pub fn decode(frame: &[u8]) -> Result<Msg> {
        let mut d = Dec::new(frame);
        let msg = match d.u8()? {
            OP_HELLO => Msg::Hello {
                version: d.u32()?,
                want_manifest: d.u8()? != 0,
                session: d.u64()?,
            },
            OP_CALL => {
                let artifact = d.str()?;
                let frees = d.ids()?;
                // kv count (4) + inputs count (4) is the smallest lane.
                let n = d.len(8)?;
                let lanes = (0..n)
                    .map(|_| {
                        Ok(Lane { kv: d.ids()?, inputs: d.tensors()? })
                    })
                    .collect::<Result<_>>()?;
                Msg::Call { artifact, frees, lanes }
            }
            OP_FRESH_KV => Msg::FreshKv { artifact: d.str()? },
            OP_FORK_KV => Msg::ForkKv { parents: d.buf_infos()? },
            OP_UPLOAD => Msg::Upload { tensor: d.tensor()? },
            OP_DOWNLOAD => Msg::Download {
                id: d.u64()?,
                dtype: DType::from_code(d.u8()?)?,
                shape: d.shape()?,
            },
            OP_SET_GLOBAL => Msg::SetGlobal {
                name: d.str()?,
                tensor: d.tensor()?,
            },
            OP_READ_GLOBAL => Msg::ReadGlobal { name: d.str()? },
            OP_RESET_GLOBAL => Msg::ResetGlobal { name: d.str()? },
            OP_FREE => Msg::Free { ids: d.ids()? },
            OP_METRICS => Msg::Metrics,
            OP_OBS_PULL => Msg::ObsPull { drain: d.u8()? != 0 },
            op => bail!("unknown request opcode {op}"),
        };
        d.finish()?;
        Ok(msg)
    }
}

impl Reply {
    pub fn encode(&self) -> Vec<u8> {
        let mut e = Enc::default();
        self.encode_body(&mut e);
        e.0
    }

    /// Tagged single-buffer encode; see [`Msg::encode_tagged`].
    pub fn encode_tagged(&self, call_id: u64) -> Vec<u8> {
        let mut e = Enc::default();
        e.u64(call_id);
        self.encode_body(&mut e);
        e.0
    }

    fn encode_body(&self, e: &mut Enc) {
        match self {
            Reply::Hello { backend, manifest_json, weights_hash } => {
                e.u8(RE_HELLO);
                e.str(backend);
                match manifest_json {
                    Some(j) => {
                        e.u8(1);
                        e.str(j);
                    }
                    None => e.u8(0),
                }
                e.u64(*weights_hash);
            }
            Reply::Lanes(lanes) => {
                e.u8(RE_LANES);
                e.u32(lanes.len() as u32);
                for lane in lanes {
                    e.tensors(&lane.outputs);
                    e.buf_infos(&lane.kv);
                }
            }
            Reply::Buffers(bs) => {
                e.u8(RE_BUFFERS);
                e.buf_infos(bs);
            }
            Reply::Tensor(t) => {
                e.u8(RE_TENSOR);
                e.tensor(t);
            }
            Reply::Unit => e.u8(RE_UNIT),
            Reply::Err(msg) => {
                e.u8(RE_ERR);
                e.str(msg);
            }
            Reply::Metrics(m) => {
                // `inflight` / `max_inflight` are deliberately not
                // wire-carried: the in-flight window is a property of
                // the *client's* connection, filled in client-side by
                // the mux after this reply decodes.
                e.u8(RE_METRICS);
                e.u64(m.calls);
                e.u64(m.lanes);
                e.u64(m.buffers);
                e.u64(m.sessions);
            }
            Reply::ObsDump { now_ns, dropped, events, metrics_json } => {
                e.u8(RE_OBS_DUMP);
                e.u64(*now_ns);
                e.u64(*dropped);
                e.owned_events(events);
                e.str(metrics_json);
            }
        }
    }

    pub fn decode(frame: &[u8]) -> Result<Reply> {
        let mut d = Dec::new(frame);
        let reply = match d.u8()? {
            RE_HELLO => {
                let backend = d.str()?;
                let manifest_json = if d.u8()? != 0 {
                    Some(d.str()?)
                } else {
                    None
                };
                let weights_hash = d.u64()?;
                Reply::Hello { backend, manifest_json, weights_hash }
            }
            RE_LANES => {
                // outputs count (4) + kv count (4) is the smallest lane.
                let n = d.len(8)?;
                let lanes = (0..n)
                    .map(|_| {
                        Ok(LaneOut {
                            outputs: d.tensors()?,
                            kv: d.buf_infos()?,
                        })
                    })
                    .collect::<Result<_>>()?;
                Reply::Lanes(lanes)
            }
            RE_BUFFERS => Reply::Buffers(d.buf_infos()?),
            RE_TENSOR => Reply::Tensor(d.tensor()?),
            RE_UNIT => Reply::Unit,
            RE_ERR => Reply::Err(d.str()?),
            RE_METRICS => Reply::Metrics(ExecMetrics {
                calls: d.u64()?,
                lanes: d.u64()?,
                buffers: d.u64()?,
                sessions: d.u64()?,
                ..ExecMetrics::default()
            }),
            RE_OBS_DUMP => Reply::ObsDump {
                now_ns: d.u64()?,
                dropped: d.u64()?,
                events: d.owned_events()?,
                metrics_json: d.str()?,
            },
            op => bail!("unknown reply opcode {op}"),
        };
        d.finish()?;
        Ok(reply)
    }
}

// ----------------------------------------------------------------------------
// Handshake document: manifest + prompts + vocab as one JSON string
// ----------------------------------------------------------------------------

/// What a client learns from the manifest handshake — enough to build a
/// fully functional [`crate::runtime::Runtime`] over the connection.
pub struct HelloInfo {
    pub backend: String,
    pub manifest: Manifest,
    pub prompts: BTreeMap<String, PromptSet>,
    pub vocab: Option<Vec<String>>,
    /// Executor's weights fingerprint from the handshake (0 = unknown).
    pub weights_hash: u64,
}

fn sample_to_json(s: &PromptSample) -> Json {
    let ids = |v: &[u32]| {
        Json::Arr(v.iter().map(|&t| Json::Num(t as f64)).collect())
    };
    let mut o = BTreeMap::new();
    o.insert("task".to_string(), Json::Num(s.task as f64));
    o.insert("max_new".to_string(), Json::Num(s.max_new as f64));
    o.insert("prompt".to_string(), ids(&s.prompt));
    o.insert("answer".to_string(), ids(&s.answer));
    Json::Obj(o)
}

fn sample_from_json(j: &Json) -> Result<PromptSample> {
    let ids = |j: &Json| -> Result<Vec<u32>> {
        j.as_arr()
            .context("token array")?
            .iter()
            .map(|v| Ok(v.as_usize().context("token id")? as u32))
            .collect()
    };
    Ok(PromptSample {
        task: j.get("task").as_usize().context("sample task")? as u32,
        max_new: j.get("max_new").as_usize().context("sample max_new")?,
        prompt: ids(j.get("prompt"))?,
        answer: ids(j.get("answer"))?,
    })
}

/// Serialize the executor's manifest, in-memory prompt sets, and
/// vocabulary as the handshake JSON document.
pub fn hello_json(
    manifest: &Manifest,
    prompts: &BTreeMap<String, PromptSet>,
    vocab: Option<&[String]>,
) -> String {
    let mut root = BTreeMap::new();
    root.insert("manifest".to_string(), manifest.to_wire_json());
    let sets: BTreeMap<String, Json> = prompts
        .iter()
        .map(|(task, set)| {
            (
                task.clone(),
                Json::Arr(set.samples.iter().map(sample_to_json).collect()),
            )
        })
        .collect();
    root.insert("prompts".to_string(), Json::Obj(sets));
    root.insert(
        "vocab".to_string(),
        match vocab {
            Some(words) => Json::Arr(
                words.iter().map(|w| Json::Str(w.clone())).collect(),
            ),
            None => Json::Null,
        },
    );
    Json::Obj(root).to_string()
}

/// Parse the handshake document back into client-side structures.
/// `origin` tags the reconstructed manifest's `dir` (e.g. the address).
pub fn parse_hello(origin: &str, backend: String, text: &str) -> Result<HelloInfo> {
    let j = Json::parse(text).context("parsing handshake json")?;
    let manifest = Manifest::from_wire_json(origin, j.get("manifest"))?;
    let mut prompts = BTreeMap::new();
    if let Some(sets) = j.get("prompts").as_obj() {
        for (task, arr) in sets {
            let samples = arr
                .as_arr()
                .with_context(|| format!("prompt set '{task}'"))?
                .iter()
                .map(sample_from_json)
                .collect::<Result<_>>()?;
            prompts.insert(task.clone(), PromptSet { samples });
        }
    }
    let vocab = match j.get("vocab") {
        Json::Arr(words) => Some(
            words
                .iter()
                .map(|w| Ok(w.as_str().context("vocab word")?.to_string()))
                .collect::<Result<Vec<_>>>()?,
        ),
        _ => None,
    };
    Ok(HelloInfo { backend, manifest, prompts, vocab, weights_hash: 0 })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_msg(m: Msg) {
        let enc = m.encode();
        assert_eq!(Msg::decode(&enc).unwrap(), m);
    }

    fn roundtrip_reply(r: Reply) {
        let enc = r.encode();
        assert_eq!(Reply::decode(&enc).unwrap(), r);
    }

    #[test]
    fn messages_roundtrip_bitwise() {
        roundtrip_msg(Msg::Hello {
            version: VERSION,
            want_manifest: true,
            session: 0xDEAD_BEEF_0451,
        });
        roundtrip_msg(Msg::Call {
            artifact: "draft_block".into(),
            frees: vec![3, 9],
            lanes: vec![
                Lane {
                    kv: vec![1, 2],
                    inputs: vec![
                        Tensor::scalar_i32(-7),
                        Tensor::f32(vec![2, 3], vec![0.5, -1.25, f32::MIN_POSITIVE, 0.0, 1e-30, 3.5]),
                    ],
                },
                Lane { kv: vec![], inputs: vec![] },
            ],
        });
        roundtrip_msg(Msg::FreshKv { artifact: "prefill_shallow".into() });
        roundtrip_msg(Msg::ForkKv {
            parents: vec![
                BufInfo { id: 11, dtype: DType::F32, shape: vec![2, 160, 16] },
                BufInfo { id: 12, dtype: DType::F32, shape: vec![2, 160, 16] },
            ],
        });
        roundtrip_msg(Msg::ForkKv { parents: vec![] });
        roundtrip_msg(Msg::Upload { tensor: Tensor::i32(vec![3], vec![1, -2, 3]) });
        roundtrip_msg(Msg::Download {
            id: 42,
            dtype: DType::F32,
            shape: vec![2, 160, 16],
        });
        roundtrip_msg(Msg::SetGlobal {
            name: "lora.A".into(),
            tensor: Tensor::zeros_f32(vec![4, 2]),
        });
        roundtrip_msg(Msg::ReadGlobal { name: "lora.B".into() });
        roundtrip_msg(Msg::ResetGlobal { name: "adam.mA".into() });
        roundtrip_msg(Msg::Free { ids: vec![7] });
        roundtrip_msg(Msg::Metrics);
        roundtrip_msg(Msg::ObsPull { drain: false });
        roundtrip_msg(Msg::ObsPull { drain: true });
    }

    #[test]
    fn replies_roundtrip_bitwise() {
        roundtrip_reply(Reply::Hello {
            backend: "reference".into(),
            manifest_json: Some("{\"a\":1}".into()),
            weights_hash: 0x00C0_FFEE_D00D_F00D,
        });
        roundtrip_reply(Reply::Hello {
            backend: "pjrt".into(),
            manifest_json: None,
            weights_hash: 0,
        });
        roundtrip_reply(Reply::Lanes(vec![LaneOut {
            outputs: vec![Tensor::f32(vec![2], vec![1.5e-39, -0.0])],
            kv: vec![BufInfo { id: 5, dtype: DType::F32, shape: vec![2, 4] }],
        }]));
        roundtrip_reply(Reply::Buffers(vec![
            BufInfo { id: 1, dtype: DType::I32, shape: vec![] },
        ]));
        roundtrip_reply(Reply::Tensor(Tensor::scalar_f32(2.5)));
        roundtrip_reply(Reply::Unit);
        roundtrip_reply(Reply::Err("boom".into()));
        // The window-depth gauges are client-filled, not wire-carried,
        // so only the zeroed form roundtrips.
        roundtrip_reply(Reply::Metrics(ExecMetrics {
            calls: 12,
            lanes: 96,
            buffers: 7,
            sessions: 2,
            ..ExecMetrics::default()
        }));
        // Clock-ping form: no events, no metrics document.
        roundtrip_reply(Reply::ObsDump {
            now_ns: 123_456_789,
            dropped: 0,
            events: vec![],
            metrics_json: String::new(),
        });
        // Drain form: owned events with every arg kind, including a
        // negative-integer arg and an exact float payload.
        roundtrip_reply(Reply::ObsDump {
            now_ns: u64::MAX / 3,
            dropped: 17,
            events: vec![
                OwnedEvent {
                    name: "exec".into(),
                    cat: "exec".into(),
                    ph: 'X',
                    ts_ns: 1_000_000,
                    dur_ns: 42_000,
                    tid: 3,
                    args: vec![
                        ("op".into(), TraceArg::S("call".into())),
                        ("id".into(), TraceArg::I(-1)),
                        ("ema".into(), TraceArg::F(0.1 + 0.2)),
                    ],
                },
                OwnedEvent {
                    name: "mark".into(),
                    cat: "exec".into(),
                    ph: 'i',
                    ts_ns: -5,
                    dur_ns: 0,
                    tid: 1,
                    args: vec![],
                },
            ],
            metrics_json: "{\"counters\":{}}".into(),
        });
    }

    #[test]
    fn obs_dump_rejects_garbage_events() {
        // Bad trace-arg code inside an otherwise valid event.
        let good = Reply::ObsDump {
            now_ns: 1,
            dropped: 0,
            events: vec![OwnedEvent {
                name: "e".into(),
                cat: "c".into(),
                ph: 'X',
                ts_ns: 0,
                dur_ns: 0,
                tid: 0,
                args: vec![("k".into(), TraceArg::I(9))],
            }],
            metrics_json: String::new(),
        };
        let mut enc = good.encode();
        // The arg-kind tag is 9 bytes from the end (tag + u64 payload);
        // stomp it with an invalid code.
        let n = enc.len();
        // layout tail: ... args: key("k") tag(0) u64(9) metrics_json len(4)
        enc[n - 4 - 8 - 1] = 250;
        assert!(Reply::decode(&enc).is_err());
        // Implausible event count must error before allocating.
        let mut e = vec![RE_OBS_DUMP];
        e.extend_from_slice(&1u64.to_le_bytes());
        e.extend_from_slice(&0u64.to_le_bytes());
        e.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(Reply::decode(&e).is_err());
    }

    #[test]
    fn exec_metrics_occupancy() {
        let m = ExecMetrics {
            calls: 4,
            lanes: 10,
            buffers: 0,
            sessions: 1,
            ..ExecMetrics::default()
        };
        assert!((m.occupancy() - 2.5).abs() < 1e-12);
        assert_eq!(ExecMetrics::default().occupancy(), 0.0);
    }

    #[test]
    fn tagged_frames_roundtrip_and_reject_runts() {
        let payload = Msg::Metrics.encode();
        let frame = tag(0xABCD_EF01_2345_6789, &payload);
        let (id, body) = untag(&frame).unwrap();
        assert_eq!(id, 0xABCD_EF01_2345_6789);
        assert_eq!(body, &payload[..]);
        assert!(matches!(Msg::decode(body).unwrap(), Msg::Metrics));
        // The single-buffer hot-path encode produces identical bytes.
        assert_eq!(Msg::Metrics.encode_tagged(0xABCD_EF01_2345_6789), frame);
        let r = Reply::Unit;
        assert_eq!(r.encode_tagged(7), tag(7, &r.encode()));
        // An empty payload is legal framing (the codec rejects it later).
        let (id, body) = untag(&tag(7, &[])).unwrap();
        assert_eq!((id, body.len()), (7, 0));
        // A frame shorter than the id prefix is a protocol violation.
        assert!(untag(&[1, 2, 3]).is_err());
    }

    #[test]
    fn float_bits_survive_exactly() {
        // Subnormals, negative zero, and extreme exponents must cross
        // the wire bit-for-bit (losslessness depends on it).
        let vals = vec![-0.0f32, f32::MIN_POSITIVE / 2.0, f32::MAX, -f32::MIN];
        let t = Tensor::f32(vec![4], vals.clone());
        let enc = Msg::Upload { tensor: t }.encode();
        let Msg::Upload { tensor } = Msg::decode(&enc).unwrap() else {
            panic!("wrong opcode");
        };
        let got = tensor.as_f32().unwrap();
        for (a, b) in vals.iter().zip(got) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn garbage_frames_are_rejected_not_panicking() {
        assert!(Msg::decode(&[]).is_err());
        assert!(Msg::decode(&[250]).is_err());
        assert!(Reply::decode(&[RE_TENSOR, 9]).is_err()); // bad dtype code
        // Truncated tensor payload.
        let mut enc = Msg::Upload {
            tensor: Tensor::f32(vec![4], vec![0.0; 4]),
        }
        .encode();
        enc.truncate(enc.len() - 3);
        assert!(Msg::decode(&enc).is_err());
        // Trailing bytes.
        let mut enc = Msg::Free { ids: vec![1] }.encode();
        enc.push(0);
        assert!(Msg::decode(&enc).is_err());
        // Implausible collection length must error, not allocate.
        let mut e = vec![OP_FREE];
        e.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(Msg::decode(&e).is_err());
    }

    #[test]
    fn hello_document_roundtrips() {
        use crate::runtime::reference::{synth, ReferenceConfig};
        let cfg = ReferenceConfig::default();
        let manifest = synth::manifest(&cfg);
        let prompts = synth::prompt_sets(&cfg);
        let vocab = synth::vocab(&cfg);
        let doc = hello_json(&manifest, &prompts, Some(&vocab));
        let info = parse_hello("loopback", "reference".into(), &doc).unwrap();
        assert_eq!(info.backend, "reference");
        assert_eq!(info.manifest.artifacts.len(), manifest.artifacts.len());
        let spec = info.manifest.artifact("draft_block").unwrap();
        let orig = manifest.artifact("draft_block").unwrap();
        assert_eq!(spec.params.len(), orig.params.len());
        for (a, b) in spec.params.iter().zip(&orig.params) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.shape, b.shape);
            assert_eq!(a.dtype, b.dtype);
            assert_eq!(a.role, b.role);
        }
        assert_eq!(
            info.manifest.spec_usize("k_spec").unwrap(),
            manifest.spec_usize("k_spec").unwrap()
        );
        assert_eq!(info.prompts["qa"].samples[0].prompt,
                   prompts["qa"].samples[0].prompt);
        assert_eq!(info.vocab.as_deref(), Some(&vocab[..]));
    }
}
