//! Backend-level fault injection: a delegating [`Backend`] that fails
//! every `every`-th `call_batched`, at most `max_failures` times.
//!
//! Pairs with [`crate::runtime::Runtime::map_backend`] — the chaos
//! tests wrap the reference backend to prove the batched scheduler
//! absorbs chunk failures through `fail_lane` without wedging a tick
//! (`tests/sched.rs`, plus the scheduler's accounting regression test).
//! The failure cap is what makes those tests deterministic rather than
//! probabilistic: it bounds worst-case lane kills so "some lanes
//! survive" is a guarantee, not a likelihood.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{bail, Result};

use super::backend::{Backend, BatchItem, Buffer, CallOut, ExecutorStatus};
use super::manifest::ArtifactSpec;
use super::tensor::{DType, Tensor};

pub struct FlakyBackend {
    inner: Arc<dyn Backend>,
    every: u64,
    max_failures: u64,
    calls: AtomicU64,
    failures: AtomicU64,
}

impl FlakyBackend {
    /// Fail the `every`-th, `2*every`-th, ... batched call, stopping
    /// after `max_failures` injected failures.
    pub fn new(
        inner: Arc<dyn Backend>,
        every: u64,
        max_failures: u64,
    ) -> FlakyBackend {
        assert!(every >= 1);
        FlakyBackend {
            inner,
            every,
            max_failures,
            calls: AtomicU64::new(0),
            failures: AtomicU64::new(0),
        }
    }

    /// Batched calls observed so far (failed ones included).
    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::Relaxed)
    }

    /// Failures injected so far.
    pub fn failures(&self) -> u64 {
        self.failures.load(Ordering::Relaxed).min(self.max_failures)
    }
}

impl Backend for FlakyBackend {
    fn name(&self) -> &'static str {
        "flaky"
    }

    fn call(&self, spec: &ArtifactSpec, kv: &[Buffer], inputs: &[Tensor])
        -> Result<CallOut>
    {
        self.inner.call(spec, kv, inputs)
    }

    fn call_batched(
        &self,
        spec: &ArtifactSpec,
        batch: &[BatchItem<'_>],
    ) -> Result<Vec<CallOut>> {
        let n = self.calls.fetch_add(1, Ordering::Relaxed) + 1;
        if n % self.every == 0
            && self.failures.fetch_add(1, Ordering::Relaxed) < self.max_failures
        {
            bail!("injected chunk failure (batched call #{n})");
        }
        self.inner.call_batched(spec, batch)
    }

    fn call_batched_partial(
        &self,
        spec: &ArtifactSpec,
        batch: &[BatchItem<'_>],
    ) -> Vec<Result<CallOut>> {
        // An injected fault kills the whole chunk (that is this
        // wrapper's failure model), but a healthy call must delegate to
        // the inner backend's own partial path — wrapping a sharded
        // backend must not collapse its per-shard failure domains.
        let n = self.calls.fetch_add(1, Ordering::Relaxed) + 1;
        if n % self.every == 0
            && self.failures.fetch_add(1, Ordering::Relaxed) < self.max_failures
        {
            return batch
                .iter()
                .map(|_| {
                    Err(anyhow::anyhow!("injected chunk failure (batched call #{n})"))
                })
                .collect();
        }
        self.inner.call_batched_partial(spec, batch)
    }

    fn fresh_kv(&self, spec: &ArtifactSpec) -> Result<Vec<Buffer>> {
        self.inner.fresh_kv(spec)
    }

    fn fresh_kv_keyed(&self, spec: &ArtifactSpec, key: u64) -> Result<Vec<Buffer>> {
        // Forwarded, not defaulted: a wrapped sharded backend must keep
        // its keyed placement.
        self.inner.fresh_kv_keyed(spec, key)
    }

    fn fork_kv(&self, spec: &ArtifactSpec, parents: &[Buffer]) -> Result<Vec<Buffer>> {
        // Forwarded, not defaulted: a wrapped remote backend must mint
        // real server-side forks, not local handle clones.
        self.inner.fork_kv(spec, parents)
    }

    fn kv_placement_hint(&self) -> Option<u64> {
        self.inner.kv_placement_hint()
    }

    fn upload(&self, t: &Tensor) -> Result<Buffer> {
        self.inner.upload(t)
    }

    fn to_host(&self, b: &Buffer, dtype: DType, shape: &[usize]) -> Result<Tensor> {
        self.inner.to_host(b, dtype, shape)
    }

    fn set_global(&self, name: &str, t: &Tensor) -> Result<()> {
        self.inner.set_global(name, t)
    }

    fn read_global(&self, name: &str) -> Result<Tensor> {
        self.inner.read_global(name)
    }

    fn reset_global(&self, name: &str) -> Result<()> {
        self.inner.reset_global(name)
    }

    fn executor_status(&self) -> Vec<ExecutorStatus> {
        self.inner.executor_status()
    }

    fn weights_fingerprint(&self) -> Option<u64> {
        self.inner.weights_fingerprint()
    }

    fn obs_pull(&self) -> Result<Vec<crate::runtime::remote::ShardObs>> {
        self.inner.obs_pull()
    }

    // `call_batched_submit` deliberately stays on the trait default:
    // it routes through this wrapper's `call_batched_partial`, so the
    // scheduler's submit path keeps the fault injection (at the cost of
    // executing at submit time — fine for an in-process test double).
}
