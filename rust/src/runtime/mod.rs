//! Runtime layer: a [`Manifest`]-driven artifact executor over a
//! pluggable [`Backend`].
//!
//! Two backends implement the seam:
//!
//!   * [`reference::ReferenceBackend`] — deterministic pure-Rust
//!     split-transformer interpreter with synthetic weights, prompts,
//!     and vocabulary, created by [`Runtime::load_reference`]. Always
//!     available; the hermetic test suite runs on it unconditionally.
//!   * `pjrt::PjrtBackend` (cargo feature `pjrt`) — compiles the AOT
//!     HLO in `artifacts/` on the PJRT CPU client, created by
//!     [`Runtime::load`]. Used when `DVI_ARTIFACTS` points at a real
//!     export.
//!
//! [`Runtime::load_auto`] picks PJRT when the feature is on and a
//! manifest exists, and falls back to the reference backend otherwise,
//! so every binary in the repo runs out of the box.

pub mod backend;
pub mod chaos;
pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod reference;
pub mod remote;
pub mod tensor;
pub mod weights;

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

pub use backend::{
    Backend, BatchHandle, BatchItem, Buffer, CallOut, ExecMetrics,
    ExecutorStatus, ReadyBatch,
};
pub use manifest::{ArtifactSpec, Manifest, Port, Role};
pub use reference::{ReferenceBackend, ReferenceConfig};
pub use remote::shard::{shard_for_key, ShardedRemoteBackend};
pub use remote::RemoteBackend;
pub use tensor::{DType, Tensor, TensorData};
pub use weights::{load_weights, WeightMap};

use crate::tokenizer::Tokenizer;
use crate::workload::PromptSet;

/// Default seed for [`Runtime::load_reference`] fallbacks.
pub const REFERENCE_SEED: u64 = 0xD5EED;

/// One executable artifact: the manifest spec plus a backend handle.
/// `call` shape-checks against the manifest at call time, so a
/// mismatched artifact fails loudly rather than corrupting a decode.
pub struct Artifact {
    pub spec: ArtifactSpec,
    backend: Arc<dyn Backend>,
}

impl Artifact {
    /// Shape/dtype-check one lane's kv + inputs against the manifest.
    fn check_lane(&self, kv: &[Buffer], inputs: &[Tensor]) -> Result<()> {
        let n_kv = self.spec.params_with_role(Role::Kv).count();
        if kv.len() != n_kv {
            bail!("{}: expected {} kv buffers, got {}",
                  self.spec.name, n_kv, kv.len());
        }
        let in_ports: Vec<&Port> = self.spec.params_with_role(Role::In).collect();
        if inputs.len() != in_ports.len() {
            bail!("{}: expected {} inputs, got {}",
                  self.spec.name, in_ports.len(), inputs.len());
        }
        for (t, port) in inputs.iter().zip(&in_ports) {
            if t.shape != port.shape || t.dtype() != port.dtype {
                bail!(
                    "{}: input '{}' shape/dtype mismatch (got {:?}, manifest {:?})",
                    self.spec.name, port.name, t.shape, port.shape
                );
            }
        }
        Ok(())
    }

    /// Check a backend result against the manifest's output ports.
    fn check_out(&self, out: &CallOut) -> Result<()> {
        let n_out = self.spec.outputs_with_role(Role::Out).count();
        let n_kv_out = self.spec.outputs_with_role(Role::Kv).count();
        if out.outputs.len() != n_out || out.kv.len() != n_kv_out {
            bail!(
                "{}: backend returned {} outputs / {} kv, manifest says {} / {}",
                self.spec.name, out.outputs.len(), out.kv.len(), n_out, n_kv_out
            );
        }
        Ok(())
    }

    /// Execute. `kv` must match the artifact's kv params in order;
    /// `inputs` must match role=in params in order.
    pub fn call(&self, kv: &[Buffer], inputs: &[Tensor]) -> Result<CallOut> {
        self.check_lane(kv, inputs)?;
        let out = self.backend.call(&self.spec, kv, inputs)?;
        self.check_out(&out)?;
        Ok(out)
    }

    /// Execute one artifact over many independent sequences in a single
    /// backend call (the continuous-batching hot path). Every lane is
    /// shape-checked like [`Artifact::call`]; lane i's result is bitwise
    /// identical to a standalone call with the same kv/inputs.
    pub fn call_batched(&self, batch: &[BatchItem<'_>]) -> Result<Vec<CallOut>> {
        for item in batch {
            self.check_lane(item.kv, item.inputs)?;
        }
        let outs = self.backend.call_batched(&self.spec, batch)?;
        if outs.len() != batch.len() {
            bail!(
                "{}: batched backend returned {} results for {} lanes",
                self.spec.name, outs.len(), batch.len()
            );
        }
        for out in &outs {
            self.check_out(out)?;
        }
        Ok(outs)
    }

    /// [`Artifact::call_batched`] with per-lane failure granularity: the
    /// outer `Err` is reserved for caller bugs (shape mismatches, a
    /// backend violating its contract); the inner per-lane `Err`s are
    /// execution failures — on a sharded remote backend, only the lanes
    /// owned by a dead executor. The scheduler drives this seam so one
    /// lost shard degrades a tick instead of wedging it.
    pub fn call_batched_partial(
        &self,
        batch: &[BatchItem<'_>],
    ) -> Result<Vec<Result<CallOut>>> {
        for item in batch {
            self.check_lane(item.kv, item.inputs)?;
        }
        let outs = self.backend.call_batched_partial(&self.spec, batch);
        if outs.len() != batch.len() {
            bail!(
                "{}: batched backend returned {} results for {} lanes",
                self.spec.name, outs.len(), batch.len()
            );
        }
        for out in outs.iter().flatten() {
            self.check_out(out)?;
        }
        Ok(outs)
    }

    /// Submit a batched call without waiting: the returned handle
    /// resolves to what [`Artifact::call_batched_partial`]'s inner
    /// vector would hold (caller bugs surface as per-lane errors).
    /// Lanes are shape-checked here at submit time; backend outputs are
    /// checked when the handle is drained. On the pipelined remote
    /// backends, chunks submitted back-to-back genuinely overlap —
    /// across shards and within one shard's in-flight window — which is
    /// how a scheduler tick keeps the whole fleet busy.
    pub fn call_batched_submit(&self, batch: &[BatchItem<'_>]) -> Box<dyn BatchHandle> {
        for item in batch {
            if let Err(e) = self.check_lane(item.kv, item.inputs) {
                let msg = format!("{e:#}");
                return Box::new(ReadyBatch(
                    batch
                        .iter()
                        .map(|_| Err(anyhow::anyhow!("{msg}")))
                        .collect(),
                ));
            }
        }
        Box::new(CheckedBatch {
            inner: self.backend.call_batched_submit(&self.spec, batch),
            n: batch.len(),
            n_out: self.spec.outputs_with_role(Role::Out).count(),
            n_kv: self.spec.outputs_with_role(Role::Kv).count(),
            name: self.spec.name.clone(),
        })
    }
}

/// Completion handle minted by [`Artifact::call_batched_submit`]:
/// applies the same output checks [`Artifact::call_batched`] performs,
/// once the underlying backend handle resolves.
struct CheckedBatch {
    inner: Box<dyn BatchHandle>,
    n: usize,
    n_out: usize,
    n_kv: usize,
    name: String,
}

impl BatchHandle for CheckedBatch {
    fn wait(self: Box<Self>) -> Vec<Result<CallOut>> {
        let CheckedBatch { inner, n, n_out, n_kv, name } = *self;
        let outs = inner.wait();
        if outs.len() != n {
            let msg = format!(
                "{name}: batched backend returned {} results for {n} lanes",
                outs.len()
            );
            return (0..n).map(|_| Err(anyhow::anyhow!("{msg}"))).collect();
        }
        outs.into_iter()
            .map(|r| -> Result<CallOut> {
                let out = r?;
                if out.outputs.len() != n_out || out.kv.len() != n_kv {
                    bail!(
                        "{name}: backend returned {} outputs / {} kv, \
                         manifest says {n_out} / {n_kv}",
                        out.outputs.len(),
                        out.kv.len()
                    );
                }
                Ok(out)
            })
            .collect()
    }
}

pub struct Runtime {
    pub manifest: Manifest,
    backend: Arc<dyn Backend>,
    artifacts: BTreeMap<String, Arc<Artifact>>,
    /// In-memory prompt sets (reference backend); empty for PJRT, whose
    /// prompts live in `manifest.prompts` files.
    prompts: BTreeMap<String, PromptSet>,
    /// In-memory vocabulary (reference backend).
    vocab: Option<Vec<String>>,
}

impl Runtime {
    /// Fully hermetic runtime: generated manifest, seeded synthetic
    /// weights, in-memory prompts and vocabulary. Zero files on disk.
    pub fn load_reference(seed: u64) -> Result<Runtime> {
        Runtime::load_reference_with(ReferenceConfig { seed, ..Default::default() })
    }

    pub fn load_reference_with(cfg: ReferenceConfig) -> Result<Runtime> {
        let manifest = reference::synth::manifest(&cfg);
        let prompts = reference::synth::prompt_sets(&cfg);
        let vocab = reference::synth::vocab(&cfg);
        let backend: Arc<dyn Backend> = Arc::new(ReferenceBackend::new(cfg)?);
        let artifacts = manifest
            .artifacts
            .values()
            .map(|spec| {
                (
                    spec.name.clone(),
                    Arc::new(Artifact { spec: spec.clone(), backend: backend.clone() }),
                )
            })
            .collect();
        log::debug("reference runtime ready (hermetic, no artifacts on disk)");
        Ok(Runtime { manifest, backend, artifacts, prompts, vocab: Some(vocab) })
    }

    /// Load compiled artifacts from `dir` on the PJRT backend (all if
    /// `names` is None). Requires the `pjrt` cargo feature; without it
    /// this returns an error directing callers at the reference backend.
    pub fn load(dir: &Path, names: Option<&[&str]>) -> Result<Runtime> {
        #[cfg(feature = "pjrt")]
        {
            let (manifest, chosen, be) = pjrt::PjrtBackend::load(dir, names)?;
            let backend: Arc<dyn Backend> = Arc::new(be);
            let artifacts = chosen
                .into_iter()
                .map(|spec| {
                    (
                        spec.name.clone(),
                        Arc::new(Artifact { spec, backend: backend.clone() }),
                    )
                })
                .collect();
            Ok(Runtime {
                manifest,
                backend,
                artifacts,
                prompts: BTreeMap::new(),
                vocab: None,
            })
        }
        #[cfg(not(feature = "pjrt"))]
        {
            let _ = names;
            bail!(
                "cannot load artifacts from {}: this build has no PJRT backend \
                 (rebuild with --features pjrt, or use Runtime::load_reference)",
                dir.display()
            )
        }
    }

    /// Connect to one or more remote executors
    /// (`dvi serve-backend --listen ...`) and build a runtime whose
    /// backend ships every artifact call over the wire. `addr` is a
    /// single `HOST:PORT` or a comma-separated list — two or more
    /// addresses yield a [`ShardedRemoteBackend`] that routes each
    /// sequence's KV to one executor and fans batched calls out across
    /// all of them. The manifest, prompt sets, and vocabulary come from
    /// the executors' handshakes, so engines, the scheduler, and the
    /// learner run unmodified.
    pub fn load_remote(addr: &str) -> Result<Runtime> {
        let addrs: Vec<&str> =
            addr.split(',').map(str::trim).filter(|a| !a.is_empty()).collect();
        match addrs.as_slice() {
            [] => bail!("empty remote executor address"),
            [one] => Runtime::load_remote_with(Box::new(
                remote::transport::TcpConnector { addr: one.to_string() },
            )),
            many => Runtime::load_remote_sharded(many),
        }
    }

    /// Build a runtime from an already-handshaken remote backend.
    fn assemble_remote(
        backend: Arc<dyn Backend>,
        info: remote::proto::HelloInfo,
    ) -> Runtime {
        let artifacts = info
            .manifest
            .artifacts
            .values()
            .map(|spec| {
                (
                    spec.name.clone(),
                    Arc::new(Artifact { spec: spec.clone(), backend: backend.clone() }),
                )
            })
            .collect();
        Runtime {
            manifest: info.manifest,
            backend,
            artifacts,
            prompts: info.prompts,
            vocab: info.vocab,
        }
    }

    /// [`Runtime::load_remote`] over an arbitrary connector (TCP in
    /// production, in-process loopback in the hermetic tests).
    pub fn load_remote_with(
        connector: Box<dyn remote::transport::Connector>,
    ) -> Result<Runtime> {
        let (be, info) = RemoteBackend::connect(connector)?;
        log::info(&format!(
            "remote runtime ready (executor backend: {})",
            info.backend
        ));
        Ok(Runtime::assemble_remote(Arc::new(be), info))
    }

    /// [`Runtime::load_remote_with`] pinning the per-connection
    /// in-flight window explicitly (ignoring `DVI_MUX_WINDOW`) — for
    /// tests and benches whose determinism depends on a known window.
    pub fn load_remote_with_window(
        connector: Box<dyn remote::transport::Connector>,
        window: usize,
    ) -> Result<Runtime> {
        let (be, info) =
            RemoteBackend::connect_shard_windowed(connector, 0, window)?;
        log::info(&format!(
            "remote runtime ready (executor backend: {}, window {window})",
            info.backend
        ));
        Ok(Runtime::assemble_remote(Arc::new(be), info))
    }

    /// Sharded remote runtime over a list of executor addresses — the
    /// explicit form of `load_remote("h1:p1,h2:p2")`.
    pub fn load_remote_sharded(addrs: &[&str]) -> Result<Runtime> {
        Runtime::load_remote_sharded_with(
            addrs
                .iter()
                .map(|a| {
                    Box::new(remote::transport::TcpConnector {
                        addr: a.to_string(),
                    }) as Box<dyn remote::transport::Connector>
                })
                .collect(),
        )
    }

    /// Sharded remote runtime over arbitrary connectors, one per
    /// executor: lanes are routed by the shard owning their KV, batched
    /// calls fan out concurrently, and a dead executor fails only its
    /// own lanes (the scheduler's `fail_lane` absorbs them). All
    /// executors must front identical artifacts/config — verified
    /// against shard 0's handshake at connect time.
    pub fn load_remote_sharded_with(
        connectors: Vec<Box<dyn remote::transport::Connector>>,
    ) -> Result<Runtime> {
        let shards = connectors.len();
        let (be, info) = ShardedRemoteBackend::connect(connectors)?;
        log::info(&format!(
            "sharded remote runtime ready ({shards} executors, backend: {})",
            info.backend
        ));
        Ok(Runtime::assemble_remote(Arc::new(be), info))
    }

    /// Fully hermetic sharded runtime: `shards` in-process executors,
    /// each fronting an identically seeded reference backend behind its
    /// own loopback transport — the complete multi-executor path
    /// (routing, concurrent sub-calls, per-shard failure domains) with
    /// no sockets.
    pub fn load_remote_sharded_loopback(seed: u64, shards: usize) -> Result<Runtime> {
        let mut rts = Vec::with_capacity(shards);
        for _ in 0..shards {
            rts.push(Arc::new(Runtime::load_reference(seed)?));
        }
        let connectors = remote::server::spawn_loopback_shards(rts)
            .into_iter()
            .map(|s| {
                Box::new(s.connector) as Box<dyn remote::transport::Connector>
            })
            .collect();
        Runtime::load_remote_sharded_with(connectors)
    }

    /// Fully hermetic remote runtime: spawns an in-process executor
    /// thread fronting a reference backend seeded with `seed`, reached
    /// through the loopback transport — the complete remote path
    /// (framing, codec, server dispatch, buffer table) with no sockets.
    pub fn load_remote_loopback(seed: u64) -> Result<Runtime> {
        let server = Arc::new(Runtime::load_reference(seed)?);
        Runtime::load_remote_with(Box::new(remote::server::spawn_loopback(server)))
    }

    /// [`Runtime::load_remote_loopback`] with an explicit per-connection
    /// in-flight window (`window = 1` restores the strict
    /// request/response discipline; the serial-vs-pipelined bench in
    /// `benches/remote_overhead.rs` compares the two).
    pub fn load_remote_loopback_windowed(seed: u64, window: usize) -> Result<Runtime> {
        let server = Arc::new(Runtime::load_reference(seed)?);
        let connector = remote::server::spawn_loopback(server);
        Runtime::load_remote_with_window(Box::new(connector), window)
    }

    /// [`Runtime::load_remote_loopback`] with deterministic fault
    /// injection: every `fail_every`-th client send errors (at most
    /// `max_failures` times), exercising the at-most-once /
    /// lazy-reconnect path under load.
    pub fn load_remote_loopback_chaos(
        seed: u64,
        fail_every: u64,
        max_failures: u64,
    ) -> Result<Runtime> {
        let server = Arc::new(Runtime::load_reference(seed)?);
        let plan = remote::transport::ChaosPlan::new(fail_every, max_failures);
        Runtime::load_remote_with(Box::new(remote::server::spawn_loopback_chaos(
            server, plan,
        )))
    }

    /// Hermetic runtime for tests honoring `DVI_TEST_REMOTE`: unset (or
    /// empty) yields the in-process reference backend; `loopback` routes
    /// the same reference backend through the remote executor path, so
    /// CI proves the wire seam with the identical test suite. With
    /// `DVI_TEST_SHARDS=N` (N >= 2) the loopback path spawns N
    /// executors behind the sharded client, so the same suite also
    /// proves the multi-executor path.
    pub fn load_hermetic(seed: u64) -> Result<Runtime> {
        let shards = match std::env::var("DVI_TEST_SHARDS") {
            Ok(s) if !s.is_empty() => s
                .parse::<usize>()
                .ok()
                .filter(|&n| n >= 1)
                .with_context(|| format!("bad DVI_TEST_SHARDS='{s}'"))?,
            _ => 1,
        };
        match std::env::var("DVI_TEST_REMOTE").as_deref() {
            Ok("loopback") if shards > 1 => {
                Runtime::load_remote_sharded_loopback(seed, shards)
            }
            Ok("loopback") => Runtime::load_remote_loopback(seed),
            Ok("") | Err(_) => {
                // A sharded lane without the loopback mode would test
                // zero sharded code while reporting green — refuse.
                ensure!(
                    shards <= 1,
                    "DVI_TEST_SHARDS={shards} requires DVI_TEST_REMOTE=loopback"
                );
                Runtime::load_reference(seed)
            }
            Ok(other) => bail!(
                "unsupported DVI_TEST_REMOTE='{other}' (expected 'loopback')"
            ),
        }
    }

    /// Rebuild this runtime with its backend wrapped by `wrap` — the
    /// fault-injection / instrumentation hook (`tests/sched.rs` wraps
    /// the reference backend in a chaos layer that fails every Nth
    /// batched call). Artifacts are re-bound to the wrapper.
    pub fn map_backend(
        mut self,
        wrap: impl FnOnce(Arc<dyn Backend>) -> Arc<dyn Backend>,
    ) -> Runtime {
        let backend = wrap(self.backend.clone());
        self.artifacts = self
            .manifest
            .artifacts
            .values()
            .map(|spec| {
                (
                    spec.name.clone(),
                    Arc::new(Artifact { spec: spec.clone(), backend: backend.clone() }),
                )
            })
            .collect();
        self.backend = backend;
        self
    }

    /// Backend auto-selection, in priority order: remote executor(s)
    /// named by `DVI_REMOTE` (one `dvi serve-backend` address, or a
    /// comma list — `host1:p1,host2:p2` — for a sharded fleet); PJRT
    /// when compiled in and `dir` holds a manifest; otherwise the
    /// hermetic reference backend. Every binary stays runnable with no
    /// artifacts, no Python, and no XLA.
    pub fn load_auto(dir: &Path) -> Result<Runtime> {
        if let Ok(addr) = std::env::var("DVI_REMOTE") {
            if !addr.is_empty() {
                log::info(&format!(
                    "DVI_REMOTE set — using the remote executor(s) at {addr}"
                ));
                return Runtime::load_remote(&addr);
            }
        }
        let have_manifest = dir.join("manifest.json").exists();
        if cfg!(feature = "pjrt") && have_manifest {
            Runtime::load(dir, None)
        } else {
            if have_manifest {
                log::info(&format!(
                    "artifacts found at {} but this build has no `pjrt` \
                     feature — using the reference backend (rebuild with \
                     --features pjrt to use them)",
                    dir.display()
                ));
            } else {
                log::info(&format!(
                    "no PJRT artifacts at {} — using the reference backend",
                    dir.display()
                ));
            }
            Runtime::load_reference(REFERENCE_SEED)
        }
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    pub fn artifact(&self, name: &str) -> Result<Arc<Artifact>> {
        self.artifacts
            .get(name)
            .cloned()
            .with_context(|| format!("artifact '{name}' not loaded"))
    }

    pub fn has_artifact(&self, name: &str) -> bool {
        self.artifacts.contains_key(name)
    }

    /// Fresh per-sequence KV buffers (zeros) for the given artifact's kv
    /// params.
    pub fn fresh_kv(&self, artifact: &str) -> Result<Vec<Buffer>> {
        self.backend.fresh_kv(&self.artifact(artifact)?.spec)
    }

    /// [`Runtime::fresh_kv`] with a placement key: allocations sharing a
    /// key are co-resident on one executor of a sharded backend, so a
    /// sequence's KV sets never straddle shards. In-process backends
    /// ignore the key — results are bitwise identical either way.
    pub fn fresh_kv_keyed(&self, artifact: &str, key: u64) -> Result<Vec<Buffer>> {
        self.backend.fresh_kv_keyed(&self.artifact(artifact)?.spec, key)
    }

    /// Copy-on-write fork of existing KV buffers: returns child buffers
    /// aliasing the parents' (immutable) storage but with independent
    /// lifetimes. In-process backends clone the cheap `Arc` handles;
    /// the remote backend mints fresh server-side ids on the shard
    /// owning the parents. This is the prefix-cache attach primitive.
    pub fn fork_kv(&self, artifact: &str, parents: &[Buffer]) -> Result<Vec<Buffer>> {
        self.backend.fork_kv(&self.artifact(artifact)?.spec, parents)
    }

    /// Preferred placement key for the *next* fresh KV allocation, when
    /// the backend has an opinion (sharded remote: the least-loaded
    /// shard). `None` means "caller's keying is fine".
    pub fn kv_placement_hint(&self) -> Option<u64> {
        self.backend.kv_placement_hint()
    }

    /// Health of the remote executor(s) behind this runtime (empty for
    /// in-process backends): per-shard endpoint plus the executor-side
    /// `Metrics` counters when reachable.
    pub fn executor_status(&self) -> Vec<ExecutorStatus> {
        self.backend.executor_status()
    }

    /// Drain trace events + metrics from the remote executor(s) behind
    /// this runtime, one clock-aligned [`remote::ShardObs`] per shard
    /// (empty for in-process backends — their events are already in the
    /// local tracer ring). Destructive: each executor event is returned
    /// exactly once across successive pulls.
    pub fn obs_pull(&self) -> Result<Vec<remote::ShardObs>> {
        self.backend.obs_pull()
    }

    /// Fingerprint of the weights (and initial globals) this runtime's
    /// backend serves; carried in the executor handshake so sharded
    /// clients can reject fleets with divergent weights at connect
    /// time. `None` when the backend cannot hash its weights.
    pub fn weights_fingerprint(&self) -> Option<u64> {
        self.backend.weights_fingerprint()
    }

    /// Reset a global buffer back to its initial value (used to re-init
    /// LoRA/Adam between ablation runs).
    pub fn reset_global(&self, name: &str) -> Result<()> {
        self.backend.reset_global(name)
    }

    /// Read back a named global buffer (LoRA adapters, Adam moments).
    pub fn read_global(&self, name: &str) -> Result<Tensor> {
        self.backend.read_global(name)
    }

    /// Replace a named global buffer (parity tests stage golden inputs).
    pub fn set_global(&self, name: &str, t: &Tensor) -> Result<()> {
        self.backend.set_global(name, t)
    }

    /// Upload a host tensor to a backend buffer (tests stage KV inputs).
    pub fn upload(&self, t: &Tensor) -> Result<Buffer> {
        self.backend.upload(t)
    }

    /// Download a buffer back to the host.
    pub fn to_host(&self, b: &Buffer, dtype: DType, shape: &[usize])
        -> Result<Tensor>
    {
        self.backend.to_host(b, dtype, shape)
    }

    /// In-memory prompt set for `task`, if this runtime synthesizes its
    /// own workloads (reference backend).
    pub fn synthetic_prompts(&self, task: &str) -> Option<&PromptSet> {
        self.prompts.get(task)
    }

    /// The runtime's tokenizer: in-memory for the reference backend,
    /// `vocab.json` for PJRT artifact dirs.
    pub fn tokenizer(&self) -> Result<Tokenizer> {
        match &self.vocab {
            Some(words) => Ok(Tokenizer::from_words(words.clone())),
            None => Tokenizer::load(&self.manifest.vocab_file),
        }
    }
}

/// Tiny leveled logger (no `log`/`env_logger` crates offline).
pub mod log {
    use std::sync::atomic::{AtomicU8, Ordering};

    pub static LEVEL: AtomicU8 = AtomicU8::new(1); // 0=quiet 1=info 2=debug

    pub fn set_level(l: u8) {
        LEVEL.store(l, Ordering::Relaxed);
    }

    pub fn info(msg: &str) {
        if LEVEL.load(Ordering::Relaxed) >= 1 {
            eprintln!("[dvi] {msg}");
        }
    }

    pub fn debug(msg: &str) {
        if LEVEL.load(Ordering::Relaxed) >= 2 {
            eprintln!("[dvi:debug] {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_runtime_loads_all_artifacts() {
        let rt = Runtime::load_reference(1).unwrap();
        assert_eq!(rt.backend_name(), "reference");
        for name in [
            "draft_step", "draft_block", "verify_block", "prefill_shallow",
            "prefill_deep", "prefill_full", "target_step",
            "target_verify_block", "sps_prefill", "sps_draft_step",
            "medusa_heads", "hydra_chain", "eagle_step", "train_step",
        ] {
            assert!(rt.has_artifact(name), "missing artifact {name}");
            assert!(rt.artifact(name).is_ok());
        }
        assert!(rt.artifact("nope").is_err());
        assert!(rt.synthetic_prompts("qa").is_some());
        assert!(rt.synthetic_prompts("banana").is_none());
        let tok = rt.tokenizer().unwrap();
        assert_eq!(tok.vocab_size(), rt.manifest.model_usize("vocab_size").unwrap());
    }

    #[test]
    fn artifact_call_validates_shapes() {
        let rt = Runtime::load_reference(2).unwrap();
        let art = rt.artifact("target_step").unwrap();
        let kv = rt.fresh_kv("target_step").unwrap();
        // Wrong input count.
        assert!(art.call(&kv, &[Tensor::scalar_i32(1)]).is_err());
        // Wrong kv count.
        assert!(art
            .call(&kv[..1], &[Tensor::scalar_i32(1), Tensor::scalar_i32(0)])
            .is_err());
        // Wrong dtype.
        assert!(art
            .call(&kv, &[Tensor::scalar_f32(1.0), Tensor::scalar_i32(0)])
            .is_err());
        // Correct call succeeds and chains kv.
        let out = art
            .call(&kv, &[Tensor::scalar_i32(5), Tensor::scalar_i32(0)])
            .unwrap();
        assert_eq!(out.kv.len(), kv.len());
        assert_eq!(out.outputs.len(), 2);
    }

    #[test]
    fn globals_roundtrip_through_runtime() {
        let rt = Runtime::load_reference(3).unwrap();
        let a0 = rt.read_global("lora.A").unwrap();
        let zero = Tensor::zeros_f32(a0.shape.clone());
        rt.set_global("lora.A", &zero).unwrap();
        assert_eq!(rt.read_global("lora.A").unwrap(), zero);
        rt.reset_global("lora.A").unwrap();
        assert_eq!(rt.read_global("lora.A").unwrap(), a0);
    }

    #[test]
    fn load_auto_falls_back_to_reference() {
        let rt = Runtime::load_auto(Path::new("/definitely/not/a/dir")).unwrap();
        assert_eq!(rt.backend_name(), "reference");
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn load_without_pjrt_errors_helpfully() {
        let err = Runtime::load(Path::new("artifacts"), None).unwrap_err();
        assert!(format!("{err}").contains("pjrt"));
    }
}
