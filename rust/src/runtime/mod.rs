//! PJRT runtime: loads `artifacts/` (manifest + HLO text + weights),
//! compiles executables on the CPU PJRT client, uploads weights once, and
//! exposes manifest-driven `Artifact::call`. Python never runs here.

pub mod artifact;
pub mod manifest;
pub mod tensor;
pub mod weights;

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::{Arc, RwLock};
use std::time::Instant;

use anyhow::{Context, Result};
use xla::PjRtClient;

pub use artifact::{Artifact, BufferStore, CallOut};
pub use manifest::{ArtifactSpec, Manifest, Port, Role};
pub use tensor::{DType, Tensor, TensorData};
pub use weights::{load_weights, WeightMap};

pub struct Runtime {
    pub client: PjRtClient,
    pub manifest: Manifest,
    pub store: BufferStore,
    artifacts: BTreeMap<String, Arc<Artifact>>,
    /// Host copies of weights (for buffer re-init, e.g. LoRA reset).
    pub host_weights: WeightMap,
}

impl Runtime {
    /// Load manifest + weights, compile the requested artifacts (all if
    /// `names` is None). Compilation is the startup cost; per-request
    /// paths only execute.
    pub fn load(dir: &Path, names: Option<&[&str]>) -> Result<Runtime> {
        let t0 = Instant::now();
        let manifest = Manifest::load(dir)?;
        let client = PjRtClient::cpu()?;
        let host_weights = weights::load_weights(&manifest.weights_file)?;

        // Upload weight + global tensors referenced by any chosen artifact.
        let chosen: Vec<ArtifactSpec> = match names {
            None => manifest.artifacts.values().cloned().collect(),
            Some(ns) => ns
                .iter()
                .map(|n| manifest.artifact(n).cloned())
                .collect::<Result<Vec<_>>>()?,
        };

        let mut weight_bufs = BTreeMap::new();
        let mut globals = BTreeMap::new();
        for spec in &chosen {
            for port in &spec.params {
                let target = match port.role {
                    Role::Weight => &mut weight_bufs,
                    Role::Global => &mut globals,
                    _ => continue,
                };
                if target.contains_key(&port.name) {
                    continue;
                }
                let t = host_weights.get(&port.name).with_context(|| {
                    format!("weights.bin missing '{}' ({:?})", port.name, port.role)
                })?;
                anyhow::ensure!(
                    t.shape == port.shape,
                    "weights.bin '{}' shape {:?} != manifest {:?}",
                    port.name, t.shape, port.shape
                );
                target.insert(port.name.clone(),
                              Arc::new(artifact::upload(&client, t)?));
            }
        }
        let store = BufferStore { weights: weight_bufs, globals: RwLock::new(globals) };

        let mut artifacts = BTreeMap::new();
        for spec in chosen {
            let tc = Instant::now();
            let proto = xla::HloModuleProto::from_text_file(
                spec.file.to_str().context("artifact path not utf-8")?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .with_context(|| format!("compiling {}", spec.name))?;
            log::debug(&format!(
                "compiled {} in {:.2}s", spec.name, tc.elapsed().as_secs_f64()
            ));
            artifacts.insert(spec.name.clone(),
                             Arc::new(Artifact::new(spec, exe)));
        }
        log::info(&format!(
            "runtime ready: {} artifacts, {} weight tensors in {:.2}s",
            artifacts.len(),
            store.weights.len(),
            t0.elapsed().as_secs_f64()
        ));
        Ok(Runtime { client, manifest, store, artifacts, host_weights })
    }

    pub fn artifact(&self, name: &str) -> Result<Arc<Artifact>> {
        self.artifacts
            .get(name)
            .cloned()
            .with_context(|| format!("artifact '{name}' not loaded"))
    }

    pub fn has_artifact(&self, name: &str) -> bool {
        self.artifacts.contains_key(name)
    }

    /// Reset a global buffer back to its weights.bin initial value
    /// (used to re-init LoRA/Adam between ablation runs).
    pub fn reset_global(&self, name: &str) -> Result<()> {
        let t = self
            .host_weights
            .get(name)
            .with_context(|| format!("no initial value for global '{name}'"))?;
        self.store
            .set_global(name, Arc::new(artifact::upload(&self.client, t)?));
        Ok(())
    }

    /// Fresh per-sequence KV buffers (zeros) for the given artifact's kv
    /// params. Slot garbage is fine semantically (masked), but zeros make
    /// runs reproducible.
    pub fn fresh_kv(&self, artifact: &str) -> Result<Vec<Arc<xla::PjRtBuffer>>> {
        let spec = &self.artifact(artifact)?.spec;
        let mut out = Vec::new();
        for port in spec.params_with_role(Role::Kv) {
            let t = Tensor::zeros_f32(port.shape.clone());
            out.push(Arc::new(artifact::upload(&self.client, &t)?));
        }
        Ok(out)
    }
}

/// Tiny leveled logger (no `log`/`env_logger` crates offline).
pub mod log {
    use std::sync::atomic::{AtomicU8, Ordering};

    pub static LEVEL: AtomicU8 = AtomicU8::new(1); // 0=quiet 1=info 2=debug

    pub fn set_level(l: u8) {
        LEVEL.store(l, Ordering::Relaxed);
    }

    pub fn info(msg: &str) {
        if LEVEL.load(Ordering::Relaxed) >= 1 {
            eprintln!("[dvi] {msg}");
        }
    }

    pub fn debug(msg: &str) {
        if LEVEL.load(Ordering::Relaxed) >= 2 {
            eprintln!("[dvi:debug] {msg}");
        }
    }
}
