//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! and the Rust runtime. Shapes/roles drive the generic executor; nothing
//! in Rust hard-codes model dimensions.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

use super::tensor::DType;

/// Parameter/output role (see aot.py docstring).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// Immutable tensor from weights.bin, uploaded once per process.
    Weight,
    /// Named mutable device buffer shared across artifacts (LoRA, Adam).
    Global,
    /// Per-sequence chained device buffer, caller-owned (KV caches).
    Kv,
    /// Per-call host input (tokens, positions, training batches).
    In,
    /// Per-call host output (logits, metrics).
    Out,
}

impl Role {
    fn parse(s: &str) -> Result<Role> {
        Ok(match s {
            "weight" => Role::Weight,
            "global" => Role::Global,
            "kv" => Role::Kv,
            "in" => Role::In,
            "out" => Role::Out,
            other => bail!("unknown role '{other}'"),
        })
    }

    /// Inverse of [`Role::parse`] (manifest/wire serialization).
    pub fn name(self) -> &'static str {
        match self {
            Role::Weight => "weight",
            Role::Global => "global",
            Role::Kv => "kv",
            Role::In => "in",
            Role::Out => "out",
        }
    }
}

#[derive(Debug, Clone)]
pub struct Port {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
    pub role: Role,
}

impl Port {
    fn parse(j: &Json) -> Result<Port> {
        let name = j.get("name").as_str().context("port name")?.to_string();
        let shape = j
            .get("shape")
            .as_arr()
            .context("port shape")?
            .iter()
            .map(|v| v.as_usize().context("shape dim"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = DType::from_name(j.get("dtype").as_str().context("dtype")?)?;
        let role = Role::parse(j.get("role").as_str().context("role")?)?;
        Ok(Port { name, shape, dtype, role })
    }

    pub fn elem_count(&self) -> usize {
        self.shape.iter().product()
    }

    /// Inverse of [`Port::parse`] (wire serialization for the remote
    /// executor's manifest handshake).
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("name".to_string(), Json::Str(self.name.clone()));
        o.insert(
            "shape".to_string(),
            Json::Arr(self.shape.iter().map(|&d| Json::Num(d as f64)).collect()),
        );
        o.insert("dtype".to_string(), Json::Str(self.dtype.name().to_string()));
        o.insert("role".to_string(), Json::Str(self.role.name().to_string()));
        Json::Obj(o)
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub params: Vec<Port>,
    pub outputs: Vec<Port>,
}

impl ArtifactSpec {
    /// Ports with a given role, in declaration (= HLO parameter) order.
    pub fn params_with_role(&self, role: Role) -> impl Iterator<Item = &Port> {
        self.params.iter().filter(move |p| p.role == role)
    }

    pub fn outputs_with_role(&self, role: Role) -> impl Iterator<Item = &Port> {
        self.outputs.iter().filter(move |p| p.role == role)
    }

    /// Parse one artifact entry (shared by `Manifest::load` and the
    /// remote-executor handshake — [`ArtifactSpec::to_json`] always
    /// emits `file`, so both sources must provide it).
    pub fn from_json(name: &str, dir: &Path, spec: &Json) -> Result<ArtifactSpec> {
        let file = dir.join(spec.get("file").as_str().context("file")?);
        let params = spec
            .get("params")
            .as_arr()
            .context("params")?
            .iter()
            .map(Port::parse)
            .collect::<Result<Vec<_>>>()
            .with_context(|| format!("artifact {name} params"))?;
        let outputs = spec
            .get("outputs")
            .as_arr()
            .context("outputs")?
            .iter()
            .map(Port::parse)
            .collect::<Result<Vec<_>>>()
            .with_context(|| format!("artifact {name} outputs"))?;
        Ok(ArtifactSpec { name: name.to_string(), file, params, outputs })
    }

    pub fn to_json(&self) -> Json {
        let ports = |ps: &[Port]| Json::Arr(ps.iter().map(Port::to_json).collect());
        let mut o = BTreeMap::new();
        o.insert(
            "file".to_string(),
            Json::Str(self.file.to_string_lossy().into_owned()),
        );
        o.insert("params".to_string(), ports(&self.params));
        o.insert("outputs".to_string(), ports(&self.outputs));
        Json::Obj(o)
    }
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub prompts: BTreeMap<String, PathBuf>,
    pub weights_file: PathBuf,
    pub vocab_file: PathBuf,
    pub config: Json,
    pub exposures: Json,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let mut artifacts = BTreeMap::new();
        for (name, spec) in j.get("artifacts").as_obj().context("artifacts")? {
            artifacts.insert(name.clone(), ArtifactSpec::from_json(name, dir, spec)?);
        }

        let mut prompts = BTreeMap::new();
        if let Some(obj) = j.get("prompts").as_obj() {
            for (task, rel) in obj {
                prompts.insert(task.clone(),
                               dir.join(rel.as_str().context("prompt path")?));
            }
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            artifacts,
            prompts,
            weights_file: dir.join(
                j.get("weights").as_str().unwrap_or("weights.bin")),
            vocab_file: dir.join(j.get("vocab").as_str().unwrap_or("vocab.json")),
            config: j.get("config").clone(),
            exposures: j.get("exposures").clone(),
        })
    }

    /// Serialize the executor-relevant subset (artifact specs + config +
    /// exposures) for the remote-executor handshake. Prompt/weight/vocab
    /// *paths* are deliberately omitted: a remote client has no use for
    /// the server's filesystem layout.
    pub fn to_wire_json(&self) -> Json {
        let mut arts = BTreeMap::new();
        for (name, spec) in &self.artifacts {
            arts.insert(name.clone(), spec.to_json());
        }
        let mut o = BTreeMap::new();
        o.insert("artifacts".to_string(), Json::Obj(arts));
        o.insert("config".to_string(), self.config.clone());
        o.insert("exposures".to_string(), self.exposures.clone());
        Json::Obj(o)
    }

    /// The model-identity view: artifact port layouts + config +
    /// exposures, with every filesystem detail (artifact `file` paths,
    /// `dir`, prompt/weight/vocab locations) excluded. Two executors
    /// front "the same model" iff this matches — the sharded client
    /// compares it at connect time, so identical fleets at different
    /// addresses (whose reconstructed manifests differ only by their
    /// endpoint-tagged dirs) are accepted and real spec/config
    /// divergence is still rejected.
    pub fn identity_json(&self) -> Json {
        let ports = |ps: &[Port]| Json::Arr(ps.iter().map(Port::to_json).collect());
        let mut arts = BTreeMap::new();
        for (name, spec) in &self.artifacts {
            let mut o = BTreeMap::new();
            o.insert("params".to_string(), ports(&spec.params));
            o.insert("outputs".to_string(), ports(&spec.outputs));
            arts.insert(name.clone(), Json::Obj(o));
        }
        let mut root = BTreeMap::new();
        root.insert("artifacts".to_string(), Json::Obj(arts));
        root.insert("config".to_string(), self.config.clone());
        root.insert("exposures".to_string(), self.exposures.clone());
        Json::Obj(root)
    }

    /// Rebuild a manifest from [`Manifest::to_wire_json`] output.
    /// `origin` tags `dir` and the derived paths (diagnostics only).
    pub fn from_wire_json(origin: &str, j: &Json) -> Result<Manifest> {
        let dir = PathBuf::from(format!("<remote:{origin}>"));
        let mut artifacts = BTreeMap::new();
        for (name, spec) in j.get("artifacts").as_obj().context("wire artifacts")? {
            artifacts.insert(
                name.clone(),
                ArtifactSpec::from_json(name, &dir, spec)?,
            );
        }
        Ok(Manifest {
            weights_file: dir.join("weights"),
            vocab_file: dir.join("vocab"),
            dir,
            artifacts,
            prompts: BTreeMap::new(),
            config: j.get("config").clone(),
            exposures: j.get("exposures").clone(),
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))
    }

    /// Model dimension helpers (read from the embedded config).
    pub fn model_usize(&self, key: &str) -> Result<usize> {
        self.config
            .get("model")
            .get(key)
            .as_usize()
            .with_context(|| format!("config.model.{key}"))
    }

    pub fn spec_usize(&self, key: &str) -> Result<usize> {
        self.config
            .get("spec")
            .get(key)
            .as_usize()
            .with_context(|| format!("config.spec.{key}"))
    }

    pub fn train_f64(&self, key: &str) -> Result<f64> {
        self.config
            .get("train")
            .get(key)
            .as_f64()
            .with_context(|| format!("config.train.{key}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_port() {
        let j = Json::parse(
            r#"{"name":"kv_sh_k","shape":[2,320,6,32],"dtype":"f32","role":"kv"}"#,
        )
        .unwrap();
        let p = Port::parse(&j).unwrap();
        assert_eq!(p.name, "kv_sh_k");
        assert_eq!(p.elem_count(), 2 * 320 * 6 * 32);
        assert_eq!(p.role, Role::Kv);
    }

    #[test]
    fn wire_json_roundtrips_specs_and_config() {
        let cfg = crate::runtime::reference::ReferenceConfig::default();
        let m = crate::runtime::reference::synth::manifest(&cfg);
        let wire = m.to_wire_json();
        let back = Manifest::from_wire_json("test", &wire).unwrap();
        assert_eq!(back.artifacts.len(), m.artifacts.len());
        for (name, spec) in &m.artifacts {
            let b = back.artifact(name).unwrap();
            assert_eq!(b.params.len(), spec.params.len());
            for (x, y) in b.params.iter().zip(&spec.params) {
                assert_eq!((&x.name, &x.shape, x.dtype, x.role),
                           (&y.name, &y.shape, y.dtype, y.role));
            }
            assert_eq!(b.outputs.len(), spec.outputs.len());
        }
        assert_eq!(back.config, m.config);
        assert_eq!(
            back.spec_usize("k_spec").unwrap(),
            m.spec_usize("k_spec").unwrap()
        );
    }

    #[test]
    fn reject_bad_role() {
        let j = Json::parse(
            r#"{"name":"x","shape":[],"dtype":"f32","role":"banana"}"#,
        )
        .unwrap();
        assert!(Port::parse(&j).is_err());
    }

    /// Two executors at different addresses reconstruct manifests whose
    /// wire JSON differs (endpoint-tagged artifact file paths) but whose
    /// model identity matches — the property the sharded connect check
    /// relies on. A real spec difference must still change the identity.
    #[test]
    fn identity_json_ignores_deployment_layout() {
        let cfg = crate::runtime::reference::ReferenceConfig::default();
        let m = crate::runtime::reference::synth::manifest(&cfg);
        let wire = m.to_wire_json();
        let a = Manifest::from_wire_json("tcp://h1:7600", &wire).unwrap();
        let b = Manifest::from_wire_json("tcp://h2:7600", &wire).unwrap();
        assert_ne!(
            a.to_wire_json().to_string(),
            b.to_wire_json().to_string(),
            "wire JSON embeds per-endpoint paths (why identity_json exists)"
        );
        assert_eq!(
            a.identity_json().to_string(),
            b.identity_json().to_string(),
            "identity must not depend on deployment layout"
        );
        let small = crate::runtime::reference::synth::manifest(
            &crate::runtime::reference::ReferenceConfig { d_model: 24, ..cfg },
        );
        assert_ne!(
            small.identity_json().to_string(),
            m.identity_json().to_string(),
            "a real model difference must change the identity"
        );
    }
}
