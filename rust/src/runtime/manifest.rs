//! `artifacts/manifest.json` — the contract between `python/compile/aot.py`
//! and the Rust runtime. Shapes/roles drive the generic executor; nothing
//! in Rust hard-codes model dimensions.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

use super::tensor::DType;

/// Parameter/output role (see aot.py docstring).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    /// Immutable tensor from weights.bin, uploaded once per process.
    Weight,
    /// Named mutable device buffer shared across artifacts (LoRA, Adam).
    Global,
    /// Per-sequence chained device buffer, caller-owned (KV caches).
    Kv,
    /// Per-call host input (tokens, positions, training batches).
    In,
    /// Per-call host output (logits, metrics).
    Out,
}

impl Role {
    fn parse(s: &str) -> Result<Role> {
        Ok(match s {
            "weight" => Role::Weight,
            "global" => Role::Global,
            "kv" => Role::Kv,
            "in" => Role::In,
            "out" => Role::Out,
            other => bail!("unknown role '{other}'"),
        })
    }
}

#[derive(Debug, Clone)]
pub struct Port {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
    pub role: Role,
}

impl Port {
    fn parse(j: &Json) -> Result<Port> {
        let name = j.get("name").as_str().context("port name")?.to_string();
        let shape = j
            .get("shape")
            .as_arr()
            .context("port shape")?
            .iter()
            .map(|v| v.as_usize().context("shape dim"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = DType::from_name(j.get("dtype").as_str().context("dtype")?)?;
        let role = Role::parse(j.get("role").as_str().context("role")?)?;
        Ok(Port { name, shape, dtype, role })
    }

    pub fn elem_count(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub params: Vec<Port>,
    pub outputs: Vec<Port>,
}

impl ArtifactSpec {
    /// Ports with a given role, in declaration (= HLO parameter) order.
    pub fn params_with_role(&self, role: Role) -> impl Iterator<Item = &Port> {
        self.params.iter().filter(move |p| p.role == role)
    }

    pub fn outputs_with_role(&self, role: Role) -> impl Iterator<Item = &Port> {
        self.outputs.iter().filter(move |p| p.role == role)
    }
}

#[derive(Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    pub prompts: BTreeMap<String, PathBuf>,
    pub weights_file: PathBuf,
    pub vocab_file: PathBuf,
    pub config: Json,
    pub exposures: Json,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;

        let mut artifacts = BTreeMap::new();
        for (name, spec) in j.get("artifacts").as_obj().context("artifacts")? {
            let file = dir.join(spec.get("file").as_str().context("file")?);
            let params = spec
                .get("params")
                .as_arr()
                .context("params")?
                .iter()
                .map(Port::parse)
                .collect::<Result<Vec<_>>>()
                .with_context(|| format!("artifact {name} params"))?;
            let outputs = spec
                .get("outputs")
                .as_arr()
                .context("outputs")?
                .iter()
                .map(Port::parse)
                .collect::<Result<Vec<_>>>()
                .with_context(|| format!("artifact {name} outputs"))?;
            artifacts.insert(
                name.clone(),
                ArtifactSpec { name: name.clone(), file, params, outputs },
            );
        }

        let mut prompts = BTreeMap::new();
        if let Some(obj) = j.get("prompts").as_obj() {
            for (task, rel) in obj {
                prompts.insert(task.clone(),
                               dir.join(rel.as_str().context("prompt path")?));
            }
        }

        Ok(Manifest {
            dir: dir.to_path_buf(),
            artifacts,
            prompts,
            weights_file: dir.join(
                j.get("weights").as_str().unwrap_or("weights.bin")),
            vocab_file: dir.join(j.get("vocab").as_str().unwrap_or("vocab.json")),
            config: j.get("config").clone(),
            exposures: j.get("exposures").clone(),
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest"))
    }

    /// Model dimension helpers (read from the embedded config).
    pub fn model_usize(&self, key: &str) -> Result<usize> {
        self.config
            .get("model")
            .get(key)
            .as_usize()
            .with_context(|| format!("config.model.{key}"))
    }

    pub fn spec_usize(&self, key: &str) -> Result<usize> {
        self.config
            .get("spec")
            .get(key)
            .as_usize()
            .with_context(|| format!("config.spec.{key}"))
    }

    pub fn train_f64(&self, key: &str) -> Result<f64> {
        self.config
            .get("train")
            .get(key)
            .as_f64()
            .with_context(|| format!("config.train.{key}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_port() {
        let j = Json::parse(
            r#"{"name":"kv_sh_k","shape":[2,320,6,32],"dtype":"f32","role":"kv"}"#,
        )
        .unwrap();
        let p = Port::parse(&j).unwrap();
        assert_eq!(p.name, "kv_sh_k");
        assert_eq!(p.elem_count(), 2 * 320 * 6 * 32);
        assert_eq!(p.role, Role::Kv);
    }

    #[test]
    fn reject_bad_role() {
        let j = Json::parse(
            r#"{"name":"x","shape":[],"dtype":"f32","role":"banana"}"#,
        )
        .unwrap();
        assert!(Port::parse(&j).is_err());
    }
}
