//! Loader for `artifacts/weights.bin` (and `testvecs.bin` — same format).
//!
//! Format (little-endian), written by `python/compile/aot.py`:
//!   magic  b"DVIW"
//!   u32    version (1)
//!   u32    tensor count
//!   repeated:
//!     u32        name length, then name bytes (utf-8)
//!     u8         dtype code (0 = f32, 1 = i32)
//!     u32        ndim, then ndim x u32 dims
//!     raw data   (product(dims) * 4 bytes)

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::tensor::{DType, Tensor, TensorData};

pub type WeightMap = BTreeMap<String, Tensor>;

pub fn load_weights(path: &Path) -> Result<WeightMap> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading {}", path.display()))?;
    parse_weights(&bytes).with_context(|| format!("parsing {}", path.display()))
}

pub fn parse_weights(bytes: &[u8]) -> Result<WeightMap> {
    let mut r = Cursor { b: bytes, i: 0 };
    let magic = r.take(4)?;
    if magic != b"DVIW" {
        bail!("bad magic {magic:?}");
    }
    let version = r.u32()?;
    if version != 1 {
        bail!("unsupported weights version {version}");
    }
    let count = r.u32()? as usize;
    let mut out = BTreeMap::new();
    for _ in 0..count {
        let name_len = r.u32()? as usize;
        let name = String::from_utf8(r.take(name_len)?.to_vec())
            .context("tensor name not utf-8")?;
        let dtype = DType::from_code(r.u8()?)?;
        let ndim = r.u32()? as usize;
        if ndim > 8 {
            bail!("implausible ndim {ndim} for '{name}'");
        }
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(r.u32()? as usize);
        }
        let n: usize = shape.iter().product();
        let raw = r.take(n * 4)?;
        let data = match dtype {
            DType::F32 => {
                let mut v = vec![0f32; n];
                le_copy(raw, &mut v);
                TensorData::F32(v)
            }
            DType::I32 => {
                let mut v = vec![0i32; n];
                le_copy_i32(raw, &mut v);
                TensorData::I32(v)
            }
        };
        out.insert(name, Tensor { shape, data });
    }
    if r.i != bytes.len() {
        bail!("trailing bytes after {} tensors", count);
    }
    Ok(out)
}

fn le_copy(src: &[u8], dst: &mut [f32]) {
    for (i, chunk) in src.chunks_exact(4).enumerate() {
        dst[i] = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
    }
}

fn le_copy_i32(src: &[u8], dst: &mut [i32]) {
    for (i, chunk) in src.chunks_exact(4).enumerate() {
        dst[i] = i32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
    }
}

struct Cursor<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("truncated file (wanted {n} bytes at {})", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        let s = self.take(4)?;
        Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
}

/// Streaming FNV-1a (64-bit) — the dependency-free hash behind the
/// executor handshake's weights fingerprint. Not cryptographic: it
/// guards against *operator error* (mismatched weight files across a
/// fleet), not an adversary.
pub struct Fnv64(u64);

impl Fnv64 {
    pub fn new() -> Fnv64 {
        Fnv64(0xcbf2_9ce4_8422_2325)
    }

    pub fn bytes(&mut self, b: &[u8]) {
        for &x in b {
            self.0 ^= x as u64;
            self.0 = self.0.wrapping_mul(0x100_0000_01b3);
        }
    }

    pub fn u64(&mut self, v: u64) {
        self.bytes(&v.to_le_bytes());
    }

    /// Length-prefixed, so `("ab","c")` and `("a","bc")` hash apart.
    pub fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        self.bytes(s.as_bytes());
    }

    /// Raw little-endian bits — bitwise-identical floats (and only
    /// those) hash identically, matching the fleet lockstep contract.
    pub fn f32s(&mut self, v: &[f32]) {
        self.u64(v.len() as u64);
        for x in v {
            self.bytes(&x.to_le_bytes());
        }
    }

    pub fn i32s(&mut self, v: &[i32]) {
        self.u64(v.len() as u64);
        for x in v {
            self.bytes(&x.to_le_bytes());
        }
    }

    pub fn tensor(&mut self, t: &Tensor) {
        self.u64(t.shape.len() as u64);
        for &d in &t.shape {
            self.u64(d as u64);
        }
        match &t.data {
            TensorData::F32(v) => {
                self.bytes(b"f");
                self.f32s(v);
            }
            TensorData::I32(v) => {
                self.bytes(b"i");
                self.i32s(v);
            }
        }
    }

    /// Finish, reserving 0: the wire handshake uses 0 for "backend
    /// cannot hash its weights", so a real fingerprint is never 0.
    pub fn finish(&self) -> u64 {
        self.0.max(1)
    }
}

impl Default for Fnv64 {
    fn default() -> Self {
        Self::new()
    }
}

/// Fingerprint a named tensor map (weights files, initial globals):
/// order-independent input (BTreeMap is sorted), name- and
/// shape-sensitive, bitwise over the data.
pub fn fingerprint_weights(map: &WeightMap) -> u64 {
    let mut h = Fnv64::new();
    h.u64(map.len() as u64);
    for (name, t) in map {
        h.str(name);
        h.tensor(t);
    }
    h.finish()
}

/// Writer (used by tests and by state snapshots of the online learner).
pub fn serialize_weights(map: &WeightMap) -> Vec<u8> {
    let mut out = Vec::new();
    out.extend_from_slice(b"DVIW");
    out.extend_from_slice(&1u32.to_le_bytes());
    out.extend_from_slice(&(map.len() as u32).to_le_bytes());
    for (name, t) in map {
        out.extend_from_slice(&(name.len() as u32).to_le_bytes());
        out.extend_from_slice(name.as_bytes());
        let code = match t.data {
            TensorData::F32(_) => 0u8,
            TensorData::I32(_) => 1u8,
        };
        out.push(code);
        out.extend_from_slice(&(t.shape.len() as u32).to_le_bytes());
        for &d in &t.shape {
            out.extend_from_slice(&(d as u32).to_le_bytes());
        }
        match &t.data {
            TensorData::F32(v) => {
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
            TensorData::I32(v) => {
                for x in v {
                    out.extend_from_slice(&x.to_le_bytes());
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> WeightMap {
        let mut m = BTreeMap::new();
        m.insert("a.w".into(), Tensor::f32(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]));
        m.insert("b".into(), Tensor::i32(vec![3], vec![-1, 0, 7]));
        m.insert("scalar".into(), Tensor::scalar_f32(0.5));
        m
    }

    #[test]
    fn roundtrip() {
        let m = sample();
        let bytes = serialize_weights(&m);
        let back = parse_weights(&bytes).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn rejects_bad_magic() {
        let mut bytes = serialize_weights(&sample());
        bytes[0] = b'X';
        assert!(parse_weights(&bytes).is_err());
    }

    #[test]
    fn rejects_truncation() {
        let bytes = serialize_weights(&sample());
        assert!(parse_weights(&bytes[..bytes.len() - 2]).is_err());
    }

    #[test]
    fn rejects_trailing() {
        let mut bytes = serialize_weights(&sample());
        bytes.push(0);
        assert!(parse_weights(&bytes).is_err());
    }

    #[test]
    fn fingerprint_is_stable_and_sensitive() {
        let m = sample();
        let a = fingerprint_weights(&m);
        assert_eq!(a, fingerprint_weights(&m), "fingerprint must be pure");
        assert_ne!(a, 0, "0 is reserved for 'cannot hash'");
        // One flipped bit in one tensor changes the fingerprint.
        let mut m2 = sample();
        if let Tensor { data: TensorData::F32(v), .. } =
            m2.get_mut("a.w").unwrap()
        {
            v[0] = f32::from_bits(v[0].to_bits() ^ 1);
        }
        assert_ne!(a, fingerprint_weights(&m2), "bit flip must be visible");
        // A renamed tensor changes it too.
        let mut m3 = sample();
        let t = m3.remove("b").unwrap();
        m3.insert("b2".into(), t);
        assert_ne!(a, fingerprint_weights(&m3), "rename must be visible");
        // -0.0 vs +0.0 is a bitwise difference and must be caught.
        let mut m4 = sample();
        m4.insert("scalar".into(), Tensor::scalar_f32(-0.0));
        let mut m5 = sample();
        m5.insert("scalar".into(), Tensor::scalar_f32(0.0));
        assert_ne!(fingerprint_weights(&m4), fingerprint_weights(&m5));
    }
}
