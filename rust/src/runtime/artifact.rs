//! Generic manifest-driven artifact executor.
//!
//! One `Artifact` = one AOT-compiled HLO module. `call()` assembles the
//! PJRT argument list from the four parameter roles:
//!
//!   weight  -> process-wide immutable buffers (uploaded once at startup)
//!   global  -> named mutable buffers (LoRA adapters / Adam moments);
//!              outputs with the same name atomically replace the slot
//!   kv      -> caller-owned chained buffers (per-sequence KV caches)
//!   in      -> host tensors uploaded per call
//!
//! and distributes the (untupled — see third_party/xla fork) result
//! buffers back by output role. Everything is shape-checked against the
//! manifest at call time, so a mismatched artifact fails loudly rather
//! than corrupting a decode.

use std::collections::BTreeMap;
use std::sync::{Arc, RwLock};

use anyhow::{bail, Context, Result};
use xla::{PjRtBuffer, PjRtLoadedExecutable};

use super::manifest::{ArtifactSpec, Role};
use super::tensor::{DType, Tensor, TensorData};

pub struct Artifact {
    pub spec: ArtifactSpec,
    exe: PjRtLoadedExecutable,
}

/// Result of one artifact call.
pub struct CallOut {
    /// Host outputs (role=out), in manifest order.
    pub outputs: Vec<Tensor>,
    /// New per-sequence state buffers (role=kv), in manifest order.
    pub kv: Vec<Arc<PjRtBuffer>>,
}

/// Process-wide named buffer stores.
pub struct BufferStore {
    pub weights: BTreeMap<String, Arc<PjRtBuffer>>,
    pub globals: RwLock<BTreeMap<String, Arc<PjRtBuffer>>>,
}

impl BufferStore {
    pub fn global(&self, name: &str) -> Result<Arc<PjRtBuffer>> {
        self.globals
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .with_context(|| format!("global buffer '{name}' missing"))
    }

    pub fn set_global(&self, name: &str, buf: Arc<PjRtBuffer>) {
        self.globals.write().unwrap().insert(name.to_string(), buf);
    }
}

impl Artifact {
    pub fn new(spec: ArtifactSpec, exe: PjRtLoadedExecutable) -> Artifact {
        Artifact { spec, exe }
    }

    /// Execute. `kv` must match the artifact's kv params in order;
    /// `inputs` must match role=in params in order.
    pub fn call(
        &self,
        store: &BufferStore,
        kv: &[Arc<PjRtBuffer>],
        inputs: &[Tensor],
    ) -> Result<CallOut> {
        let client = self.exe.client();
        let n_kv = self.spec.params_with_role(Role::Kv).count();
        let n_in = self.spec.params_with_role(Role::In).count();
        if kv.len() != n_kv {
            bail!("{}: expected {} kv buffers, got {}",
                  self.spec.name, n_kv, kv.len());
        }
        if inputs.len() != n_in {
            bail!("{}: expected {} inputs, got {}",
                  self.spec.name, n_in, inputs.len());
        }

        // Assemble argument list in manifest (= HLO parameter) order.
        let mut owned: Vec<Arc<PjRtBuffer>> = Vec::with_capacity(self.spec.params.len());
        let mut kv_it = kv.iter();
        let mut in_it = inputs.iter();
        for port in &self.spec.params {
            let buf = match port.role {
                Role::Weight => store
                    .weights
                    .get(&port.name)
                    .cloned()
                    .with_context(|| {
                        format!("{}: weight '{}' not uploaded",
                                self.spec.name, port.name)
                    })?,
                Role::Global => store.global(&port.name)?,
                Role::Kv => kv_it.next().unwrap().clone(),
                Role::In => {
                    let t = in_it.next().unwrap();
                    if t.shape != port.shape || t.dtype() != port.dtype {
                        bail!(
                            "{}: input '{}' shape/dtype mismatch \
                             (got {:?}, manifest {:?})",
                            self.spec.name, port.name, t.shape, port.shape
                        );
                    }
                    Arc::new(upload(client, t)?)
                }
                Role::Out => bail!("role=out in params"),
            };
            owned.push(buf);
        }
        let args: Vec<&PjRtBuffer> = owned.iter().map(|a| a.as_ref()).collect();

        let mut results = self.exe.execute_b(&args)?;
        if results.len() != 1 {
            bail!("{}: expected 1 replica, got {}", self.spec.name, results.len());
        }
        let bufs = results.pop().unwrap();
        if bufs.len() != self.spec.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {} \
                 (untuple_result fork missing?)",
                self.spec.name, self.spec.outputs.len(), bufs.len()
            );
        }

        let mut outputs = Vec::new();
        let mut kv_out = Vec::new();
        for (port, buf) in self.spec.outputs.iter().zip(bufs) {
            match port.role {
                Role::Out => outputs.push(download(&buf, port.dtype, &port.shape)?),
                Role::Kv => kv_out.push(Arc::new(buf)),
                Role::Global => store.set_global(&port.name, Arc::new(buf)),
                _ => bail!("{}: bad output role", self.spec.name),
            }
        }
        Ok(CallOut { outputs, kv: kv_out })
    }
}

pub fn upload(client: &xla::PjRtClient, t: &Tensor) -> Result<PjRtBuffer> {
    let buf = match &t.data {
        TensorData::F32(v) => client.buffer_from_host_buffer(v, &t.shape, None)?,
        TensorData::I32(v) => client.buffer_from_host_buffer(v, &t.shape, None)?,
    };
    Ok(buf)
}

pub fn download(buf: &PjRtBuffer, dtype: DType, shape: &[usize]) -> Result<Tensor> {
    let lit = buf.to_literal_sync()?;
    let t = match dtype {
        DType::F32 => Tensor::f32(shape.to_vec(), lit.to_vec::<f32>()?),
        DType::I32 => Tensor::i32(shape.to_vec(), lit.to_vec::<i32>()?),
    };
    Ok(t)
}
