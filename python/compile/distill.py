"""Offline training of the baseline speculative-decoding components.

The paper's Table 2 compares DVI against offline-trained methods (SpS,
Medusa, Hydra, EAGLE, PLD). PLD is training-free; the other four need
trained components, which this module produces — *from scratch*, against
the same frozen backbone, on the same synthetic corpus (DESIGN.md
§Substitutions):

  * SpS drafter  — an independent 2-layer mini-LM (own embed/head),
                   knowledge-distilled from the backbone (classic SD).
  * Medusa heads — 4 time-independent MLP heads over h_L predicting
                   offsets +2..+5 (the LM head covers +1).
  * Hydra heads  — sequentially-dependent head chain: state s_k =
                   silu(Ws s_{k-1} + We emb(token_k)), logits = W s_k.
  * EAGLE head   — feature-level drafter: predicts the *next h_L feature*
                   from (h_L, next-token embedding) with a residual MLP;
                   tokens come from the frozen verifier LM head. (The
                   original uses a 1-layer transformer over features; the
                   residual-MLP variant preserves the feature-drafting
                   insight at this scale — see DESIGN.md.)

All four train in ONE loop sharing each batch's teacher forward (the
dominant cost), with independent Adam states. Prompt exposures per
component are logged to `artifacts/exposures.json` for the Table-1 budget
comparison harness.
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus
from . import model as M
from .config import DEFAULT_MODEL, ModelConfig
from .pretrain import adam_init, adam_update

# Baseline-component geometry (exported into the manifest by aot.py).
SPS_CFG = ModelConfig(d_model=128, n_layers=2, n_heads=4, d_ff=384)
MEDUSA_HEADS = 4
MEDUSA_HIDDEN = 256
HYDRA_HIDDEN = 256
EAGLE_HIDDEN = 384


# ----------------------------------------------------------------------------
# Component initializers
# ----------------------------------------------------------------------------

def init_components(mcfg: ModelConfig, key) -> dict:
    d, v = mcfg.d_model, mcfg.vocab_size
    ks = iter(jax.random.split(key, 16))

    def nrm(shape, scale):
        return (jax.random.normal(next(ks), shape) * scale).astype(jnp.float32)

    sps = M.init_params(SPS_CFG, next(ks))
    med = {
        "U": nrm((MEDUSA_HEADS, d, MEDUSA_HIDDEN), (2.0 / (d + MEDUSA_HIDDEN)) ** 0.5),
        "W": nrm((MEDUSA_HEADS, MEDUSA_HIDDEN, v), (2.0 / (MEDUSA_HIDDEN + v)) ** 0.5),
    }
    hy = {
        "W0": nrm((d, HYDRA_HIDDEN), (2.0 / (d + HYDRA_HIDDEN)) ** 0.5),
        "Ws": nrm((HYDRA_HIDDEN, HYDRA_HIDDEN), (2.0 / (2 * HYDRA_HIDDEN)) ** 0.5),
        "We": nrm((d, HYDRA_HIDDEN), (2.0 / (d + HYDRA_HIDDEN)) ** 0.5),
        "W": nrm((HYDRA_HIDDEN, v), (2.0 / (HYDRA_HIDDEN + v)) ** 0.5),
    }
    ea = {
        "W1": nrm((2 * d, EAGLE_HIDDEN), (2.0 / (2 * d + EAGLE_HIDDEN)) ** 0.5),
        "W2": nrm((EAGLE_HIDDEN, d), (2.0 / (EAGLE_HIDDEN + d)) ** 0.5),
    }
    return {"sps": sps, "med": med, "hy": hy, "ea": ea}


# ----------------------------------------------------------------------------
# Forward passes (training-time; decode-time twins live in aot.py artifacts)
# ----------------------------------------------------------------------------

def medusa_logits(med, hln):
    """hln [..., d] (final-norm'd h_L) -> [..., MEDUSA_HEADS, V]."""
    z = jax.nn.silu(jnp.einsum("...d,kdh->...kh", hln, med["U"]))
    return jnp.einsum("...kh,khv->...kv", z, med["W"])


def hydra_states(hy, hln, embs):
    """Teacher-forced chain. hln [..., d]; embs [..., K, d] = embeddings of
    the K tokens preceding each head's prediction. Returns [..., K, V]."""
    s = jax.nn.silu(hln @ hy["W0"])
    outs = []
    for k in range(MEDUSA_HEADS):
        s = jax.nn.silu(s @ hy["Ws"] + embs[..., k, :] @ hy["We"])
        outs.append(s @ hy["W"])
    return jnp.stack(outs, axis=-2)


def eagle_predict(ea, feat, emb):
    """feat [..., d] raw h_L, emb [..., d] next-token embedding."""
    x = jnp.concatenate([feat, emb], axis=-1)
    return feat + jax.nn.silu(x @ ea["W1"]) @ ea["W2"]


def teacher_forward(params, tokens, mcfg: ModelConfig):
    """tokens [B, T] -> (h_L raw [B, T, d], teacher logits [B, T, V])."""
    x = params["embed"][tokens]
    x = M.forward_layers_train(params, x, 0, mcfg.n_layers, mcfg)
    logits = M.rmsnorm(x, params["final_norm"], mcfg.norm_eps) @ params["lm_head"].T
    return x, logits


# ----------------------------------------------------------------------------
# Losses (one per component; shared teacher tensors)
# ----------------------------------------------------------------------------

def _soft_ce(student_logits, teacher_logits):
    p = jax.nn.softmax(teacher_logits, axis=-1)
    return -(p * jax.nn.log_softmax(student_logits, axis=-1)).sum(-1).mean()


def _hard_ce(logits, targets):
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.take_along_axis(logp, targets[..., None], axis=-1).mean()


def sps_loss(sps, tokens, teacher_logits):
    student = M.forward_train(sps, tokens, SPS_CFG)
    return _soft_ce(student, teacher_logits)


def medusa_loss(med, params, hl, tokens, mcfg):
    # Head k (0-based) at position t predicts token t+2+k.
    hln = M.rmsnorm(hl, params["final_norm"], mcfg.norm_eps)
    t_max = tokens.shape[1] - (MEDUSA_HEADS + 1)
    logits = medusa_logits(med, hln[:, :t_max])          # [B, t, K, V]
    loss = 0.0
    for k in range(MEDUSA_HEADS):
        loss += _hard_ce(logits[:, :, k], tokens[:, 2 + k: t_max + 2 + k])
    return loss / MEDUSA_HEADS


def hydra_loss(hy, params, hl, tokens, mcfg):
    hln = M.rmsnorm(hl, params["final_norm"], mcfg.norm_eps)
    t_max = tokens.shape[1] - (MEDUSA_HEADS + 1)
    # Head k consumes embedding of token t+1+k and predicts token t+2+k.
    embs = jnp.stack(
        [params["embed"][tokens[:, 1 + k: t_max + 1 + k]]
         for k in range(MEDUSA_HEADS)], axis=2)          # [B, t, K, d]
    logits = hydra_states(hy, hln[:, :t_max], embs)      # [B, t, K, V]
    loss = 0.0
    for k in range(MEDUSA_HEADS):
        loss += _hard_ce(logits[:, :, k], tokens[:, 2 + k: t_max + 2 + k])
    return loss / MEDUSA_HEADS


def eagle_loss(ea, params, hl, tokens, mcfg):
    # Predict f_{t+1} from (f_t, emb(x_{t+1})); token loss via frozen head.
    # hl covers token positions 0..S-1 where S = tokens.shape[1] - 2.
    s = tokens.shape[1] - 2
    f_in, f_tgt = hl[:, : s - 1], hl[:, 1:s]
    emb = params["embed"][tokens[:, 1:s]]
    f_pred = eagle_predict(ea, f_in, emb)
    reg = jnp.abs(f_pred - f_tgt).mean()
    logits = M.verifier_logits(params, f_pred, mcfg)
    tok = _hard_ce(logits, tokens[:, 2 : s + 1])
    return reg + 0.5 * tok


# ----------------------------------------------------------------------------
# Shared training loop
# ----------------------------------------------------------------------------

def distill(params, mcfg: ModelConfig, steps: int, batch: int, seq: int,
            seed: int, lr: float = 2e-3):
    comps = init_components(mcfg, jax.random.PRNGKey(seed))
    opts = {k: adam_init(v) for k, v in comps.items()}

    n_tok = steps * batch * (seq + 2)
    stream = np.asarray(
        corpus.token_stream(corpus.PRETRAIN_SEED + 1, n_tok), dtype=np.int32
    ).reshape(steps, batch, seq + 2)

    @jax.jit
    def step_fn(comps, opts, tokens, t):
        hl, tlogits = teacher_forward(params, tokens[:, :-2], mcfg)
        hl = jax.lax.stop_gradient(hl)
        tlogits = jax.lax.stop_gradient(tlogits)
        losses = {}

        def upd(name, loss_fn, *args):
            loss, g = jax.value_and_grad(loss_fn)(comps[name], *args)
            new_p, new_o = adam_update(comps[name], g, opts[name], lr, t=t)
            losses[name] = loss
            return new_p, new_o

        new_comps, new_opts = {}, {}
        new_comps["sps"], new_opts["sps"] = upd(
            "sps", lambda c: sps_loss(c, tokens[:, :-2], tlogits))
        new_comps["med"], new_opts["med"] = upd(
            "med", lambda c: medusa_loss(c, params, hl, tokens, mcfg))
        new_comps["hy"], new_opts["hy"] = upd(
            "hy", lambda c: hydra_loss(c, params, hl, tokens, mcfg))
        new_comps["ea"], new_opts["ea"] = upd(
            "ea", lambda c: eagle_loss(c, params, hl, tokens, mcfg))
        return new_comps, new_opts, losses

    t0 = time.time()
    for step in range(steps):
        comps, opts, losses = step_fn(comps, opts, stream[step], step + 1)
        if step % 25 == 0 or step == steps - 1:
            msg = " ".join(f"{k}={float(v):.4f}" for k, v in losses.items())
            dt = time.time() - t0
            print(f"distill {step:5d} {msg} ({dt:.0f}s)", flush=True)

    exposures = {
        # sequences seen = steps * batch; each roughly one "prompt".
        name: {"prompt_exposures": steps * batch, "optimiser_steps": steps}
        for name in ("sps", "med", "hy", "ea")
    }
    return comps, exposures


def flatten_components(comps: dict) -> dict:
    """{"sps.embed": arr, "med.U": arr, ...} for weights.bin."""
    out = {}
    for group, tree in comps.items():
        for name, arr in tree.items():
            out[f"{group}.{name}"] = np.asarray(arr)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=900)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=80)
    ap.add_argument("--backbone", default="../artifacts/backbone.npz")
    ap.add_argument("--out", default="../artifacts/heads.npz")
    ap.add_argument("--exposures", default="../artifacts/exposures.json")
    args = ap.parse_args()

    params = {k: jnp.asarray(v) for k, v in np.load(args.backbone).items()}
    comps, exposures = distill(params, DEFAULT_MODEL, args.steps, args.batch,
                               args.seq, seed=5)
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    np.savez(args.out, **flatten_components(comps))
    with open(args.exposures, "w") as f:
        json.dump(exposures, f, indent=2)
    print(f"saved {args.out}")


if __name__ == "__main__":
    main()
