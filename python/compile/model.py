"""L2 JAX model: llama-style tiny backbone with a self-speculative split.

Pure-functional weights (a flat dict of stacked arrays) so every decode
artifact can expose weights/state as explicit HLO parameters. Two families
of forward functions:

  * `forward_train` — full-sequence causal forward used by pretraining and
    offline distillation (pure jnp; XLA fuses it well on CPU).
  * decode-time step/block/prefill functions — the bodies of the AOT
    artifacts the Rust coordinator executes. These call the L1 Pallas
    kernels (`kernels.attention.decode_attention` for KV-cache attention,
    `kernels.lora_head.lora_head` for the LoRA draft head).

Position/KV-cache conventions (mirrored by `rust/src/spec/kv.rs`):
  * cache slot j holds K/V for sequence position j;
  * a step at position `pos` writes slot `pos` *before* attending, and
    attends to slots j <= pos (query i of a block: j <= pos+i);
  * slots strictly greater than the current decode position may hold stale
    speculative garbage — they are always overwritten before they become
    attendable. Rollback after a rejected draft is therefore O(1).

Draft head (paper §3.1): p_theta = softmax((W_S + gamma*A@B) h_k_norm)
where `W_S` is a frozen copy of the LM head, A=0 at init, and h_k_norm is
the *frozen* final RMSNorm applied to the layer-k residual stream (the
standard early-exit-head convention; see DESIGN.md §Fidelity).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

import os

from .config import ModelConfig
from .kernels.attention import decode_attention as _pallas_attention
from .kernels.lora_head import lora_head
from .kernels import ref as _ref

# L1 kernel selection for the *decode* path. The Pallas kernels are the
# default and the deliverable (TPU-shaped; verified vs ref in pytest).
# DVI_ATTN=jnp swaps decode attention for the jnp oracle at export time —
# an XLA-CPU fusion is faster than an interpret-mode grid loop on this
# substrate (EXPERIMENTS.md §Perf quantifies the gap). Numerics are
# verified identical to tolerance by the same pytest suite.
_ATTN_IMPL = os.environ.get("DVI_ATTN", "pallas")
decode_attention = (_ref.decode_attention if _ATTN_IMPL == "jnp"
                    else _pallas_attention)

# Same trade-off for the LoRA draft head (used on the per-token draft hot
# path): DVI_HEAD=jnp swaps the Pallas kernel for the jnp oracle at
# export. Gradients in train_step keep the Pallas custom-VJP path either
# way unless DVI_HEAD=jnp is set at train_step export too (it is a single
# switch — §Perf records both variants).
_HEAD_IMPL = os.environ.get("DVI_HEAD", "pallas")
if _HEAD_IMPL == "jnp":
    def lora_head(h, w, a, b, gamma):  # noqa: F811 (deliberate override)
        return _ref.lora_head(h, w, a, b, gamma)

# ----------------------------------------------------------------------------
# Parameter initialization
# ----------------------------------------------------------------------------

LAYER_TENSORS = (
    "wq", "wk", "wv", "wo", "w_gate", "w_up", "w_down", "rms_attn", "rms_mlp",
)


def init_params(cfg: ModelConfig, key) -> dict:
    """Stacked-weight dict. Layer tensors have a leading [n_layers] dim."""
    k = iter(jax.random.split(key, 16))
    d, ff, L, V = cfg.d_model, cfg.d_ff, cfg.n_layers, cfg.vocab_size
    s_attn = (2.0 / (d + d)) ** 0.5
    s_ff = (2.0 / (d + ff)) ** 0.5

    def nrm(kk, shape, scale):
        return (jax.random.normal(kk, shape) * scale).astype(jnp.float32)

    p = {
        "embed": nrm(next(k), (V, d), d ** -0.5),
        "wq": nrm(next(k), (L, d, d), s_attn),
        "wk": nrm(next(k), (L, d, d), s_attn),
        "wv": nrm(next(k), (L, d, d), s_attn),
        "wo": nrm(next(k), (L, d, d), s_attn / (2 * L) ** 0.5),
        "w_gate": nrm(next(k), (L, d, ff), s_ff),
        "w_up": nrm(next(k), (L, d, ff), s_ff),
        "w_down": nrm(next(k), (L, ff, d), s_ff / (2 * L) ** 0.5),
        "rms_attn": jnp.ones((L, d), jnp.float32),
        "rms_mlp": jnp.ones((L, d), jnp.float32),
        "final_norm": jnp.ones((d,), jnp.float32),
        "lm_head": nrm(next(k), (V, d), d ** -0.5),
    }
    return p


def init_lora(cfg: ModelConfig, key) -> dict:
    """LoRA draft-head params: A=0 (cold start == transplanted LM head)."""
    b = jax.random.normal(key, (cfg.lora_rank, cfg.d_model)) * 0.01
    return {
        "A": jnp.zeros((cfg.vocab_size, cfg.lora_rank), jnp.float32),
        "B": b.astype(jnp.float32),
    }


# ----------------------------------------------------------------------------
# Shared pieces
# ----------------------------------------------------------------------------

def rmsnorm(x, w, eps: float):
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * w


def rope(x, positions, theta: float):
    """x [..., T, H, hd], positions [T] -> rotated."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[:, None].astype(jnp.float32) * freqs[None, :]  # [T, half]
    cos = jnp.cos(ang)[:, None, :]                                 # [T, 1, half]
    sin = jnp.sin(ang)[:, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _swiglu(x, gate, up, down):
    return (jax.nn.silu(x @ gate) * (x @ up)) @ down


def _layer_weights(p: dict, i: int) -> dict:
    return {t: p[t][i] for t in LAYER_TENSORS}


# ----------------------------------------------------------------------------
# Full-sequence training forward (pretraining / distillation; pure jnp)
# ----------------------------------------------------------------------------

def _train_attention(x, lw, positions, cfg: ModelConfig):
    b, t, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    q = (x @ lw["wq"]).reshape(b, t, h, hd)
    k = (x @ lw["wk"]).reshape(b, t, h, hd)
    v = (x @ lw["wv"]).reshape(b, t, h, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    scores = jnp.einsum("bihd,bjhd->bhij", q, k) / (hd ** 0.5)
    mask = jnp.tril(jnp.ones((t, t), bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    att = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhij,bjhd->bihd", att, v).reshape(b, t, d)
    return out @ lw["wo"]


def forward_layers_train(p, x, lo: int, hi: int, cfg: ModelConfig):
    """Run layers [lo, hi) over a full sequence batch x [B, T, d]."""
    t = x.shape[1]
    positions = jnp.arange(t)

    def body(x, lw):
        xa = rmsnorm(x, lw["rms_attn"], cfg.norm_eps)
        x = x + _train_attention(xa, lw, positions, cfg)
        xm = rmsnorm(x, lw["rms_mlp"], cfg.norm_eps)
        x = x + _swiglu(xm, lw["w_gate"], lw["w_up"], lw["w_down"])
        return x, None

    stacked = {tname: p[tname][lo:hi] for tname in LAYER_TENSORS}
    x, _ = jax.lax.scan(body, x, stacked)
    return x


def forward_train(p, tokens, cfg: ModelConfig):
    """tokens [B, T] -> logits [B, T, V] (full model, causal)."""
    x = p["embed"][tokens]
    x = forward_layers_train(p, x, 0, cfg.n_layers, cfg)
    x = rmsnorm(x, p["final_norm"], cfg.norm_eps)
    return x @ p["lm_head"].T


def h_k_train(p, tokens, cfg: ModelConfig):
    """tokens [B, T] -> raw residual stream after the split layer [B, T, d]."""
    x = p["embed"][tokens]
    return forward_layers_train(p, x, 0, cfg.split_layer, cfg)


def draft_logits_train(p, lora, hk, cfg: ModelConfig):
    """Draft-head logits over a batch of h_k rows [N, d] (uses L1 kernel)."""
    hk_n = rmsnorm(hk, p["final_norm"], cfg.norm_eps)
    return lora_head(hk_n, p["draft_base"], lora["A"], lora["B"],
                     cfg.lora_gamma)


# ----------------------------------------------------------------------------
# Decode-time building blocks (KV cache; used by the AOT artifacts)
# ----------------------------------------------------------------------------

def _decode_layer(lw, x, k_cache, v_cache, pos, cfg: ModelConfig):
    """One layer over a block x [Bq, d]; caches [S, H, hd]; writes slots
    pos..pos+Bq-1 then attends (query i -> slots j <= pos+i)."""
    bq = x.shape[0]
    h, hd = cfg.n_heads, cfg.head_dim
    positions = pos + jnp.arange(bq)
    xa = rmsnorm(x, lw["rms_attn"], cfg.norm_eps)
    q = (xa @ lw["wq"]).reshape(bq, h, hd)
    k = (xa @ lw["wk"]).reshape(bq, h, hd)
    v = (xa @ lw["wv"]).reshape(bq, h, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    k_cache = jax.lax.dynamic_update_slice(k_cache, k, (pos, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, v, (pos, 0, 0))
    att = decode_attention(q, k_cache, v_cache, pos)      # L1 Pallas kernel
    x = x + att.reshape(bq, h * hd) @ lw["wo"]
    xm = rmsnorm(x, lw["rms_mlp"], cfg.norm_eps)
    x = x + _swiglu(xm, lw["w_gate"], lw["w_up"], lw["w_down"])
    return x, k_cache, v_cache


def run_layers_decode(p, x, k_caches, v_caches, pos, lo: int, hi: int,
                      cfg: ModelConfig):
    """Layers [lo, hi) over block x [Bq, d]. Caches [n_path, S, H, hd] are
    indexed by *path-local* layer (layer lo -> cache 0)."""
    new_k, new_v = [], []
    for i in range(lo, hi):
        li = i - lo
        x, kc, vc = _decode_layer(_layer_weights(p, i), x,
                                  k_caches[li], v_caches[li], pos, cfg)
        new_k.append(kc)
        new_v.append(vc)
    return x, jnp.stack(new_k), jnp.stack(new_v)


def _prefill_layer(lw, x, positions, cfg: ModelConfig):
    """Full-seq causal layer for prefill; returns (x, k, v) for caching."""
    t = x.shape[0]
    h, hd = cfg.n_heads, cfg.head_dim
    xa = rmsnorm(x, lw["rms_attn"], cfg.norm_eps)
    q = (xa @ lw["wq"]).reshape(t, h, hd)
    k = (xa @ lw["wk"]).reshape(t, h, hd)
    v = (xa @ lw["wv"]).reshape(t, h, hd)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    scores = jnp.einsum("ihd,jhd->hij", q, k) / (hd ** 0.5)
    mask = jnp.tril(jnp.ones((t, t), bool))
    scores = jnp.where(mask[None], scores, -1e30)
    att = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("hij,jhd->ihd", att, v).reshape(t, h * hd)
    x = x + out @ lw["wo"]
    xm = rmsnorm(x, lw["rms_mlp"], cfg.norm_eps)
    x = x + _swiglu(xm, lw["w_gate"], lw["w_up"], lw["w_down"])
    return x, k, v


def run_layers_prefill(p, x, lo: int, hi: int, cfg: ModelConfig,
                       cache_seq: int):
    """Layers [lo, hi) over a padded prompt x [T, d]. Returns x plus path
    KV caches [n_path, cache_seq, H, hd] (slots >= T are zero-padded)."""
    t = x.shape[0]
    positions = jnp.arange(t)
    ks, vs = [], []
    pad = cache_seq - t
    for i in range(lo, hi):
        x, k, v = _prefill_layer(_layer_weights(p, i), x, positions, cfg)
        if pad:
            k = jnp.pad(k, ((0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, pad), (0, 0), (0, 0)))
        ks.append(k)
        vs.append(v)
    return x, jnp.stack(ks), jnp.stack(vs)


def verifier_logits(p, x, cfg: ModelConfig):
    """Frozen verifier head over rows x [..., d]."""
    return rmsnorm(x, p["final_norm"], cfg.norm_eps) @ p["lm_head"].T


def draft_head_logits(p, lora_a, lora_b, hk, cfg: ModelConfig):
    """LoRA draft head over raw h_k rows [N, d] (L1 Pallas kernel)."""
    hk_n = rmsnorm(hk, p["final_norm"], cfg.norm_eps)
    return lora_head(hk_n, p["draft_base"], lora_a, lora_b, cfg.lora_gamma)
