"""Pure-jnp reference oracles for every Pallas kernel.

These are the correctness ground truth: `python/tests/test_kernels.py`
sweeps shapes/dtypes with hypothesis and asserts the Pallas implementations
(interpret=True) match these to tight tolerances, including gradients for
the custom-vjp kernels.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


# ----------------------------------------------------------------------------
# LoRA draft head: logits = h @ (W + gamma * A @ B)^T
#   h [N, d], W [V, d], A [V, r], B [r, d]  ->  [N, V]
# ----------------------------------------------------------------------------

def lora_head(h, w, a, b, gamma: float):
    z = h @ b.T                       # [N, r]
    return h @ w.T + gamma * (z @ a.T)


# ----------------------------------------------------------------------------
# Masked decode attention over a KV cache.
#   q       [Bq, H, hd]   queries for positions pos .. pos+Bq-1
#   k_cache [S, H, hd]    (positions >= pos+i already hold garbage/stale data
#   v_cache [S, H, hd]     and must be masked out)
#   pos     scalar int32  position of the first query
# Query i attends to cache slots j <= pos + i.
# ----------------------------------------------------------------------------

def decode_attention(q, k_cache, v_cache, pos):
    bq, h, hd = q.shape
    s = k_cache.shape[0]
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, dtype=q.dtype))
    # [H, Bq, S]
    scores = jnp.einsum("bhd,shd->hbs", q, k_cache) * scale
    j = jnp.arange(s)[None, None, :]
    i = jnp.arange(bq)[None, :, None]
    mask = j <= (pos + i)
    scores = jnp.where(mask, scores, jnp.asarray(-1e30, dtype=scores.dtype))
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hbs,shd->bhd", p, v_cache)


# ----------------------------------------------------------------------------
# Fused per-example loss statistics.
#   logits_theta [N, V] (drafter), logits_phi [N, V] (frozen verifier),
#   actions [N] int32, tau scalar.
# Returns per-example:
#   ce   = -log p_theta(action)
#   kl   = KL(p_theta || softmax(logits_phi / tau))
#   ent  = entropy(p_theta)
#   logp = log p_theta(action)          (= -ce; kept for PG-term clarity)
# ----------------------------------------------------------------------------

def fused_losses(logits_theta, logits_phi, actions, tau: float):
    logp_t = jax.nn.log_softmax(logits_theta, axis=-1)           # [N, V]
    logq = jax.nn.log_softmax(logits_phi / tau, axis=-1)         # [N, V]
    p_t = jnp.exp(logp_t)
    n = logits_theta.shape[0]
    rows = jnp.arange(n)
    logp_a = logp_t[rows, actions]
    ce = -logp_a
    kl = jnp.sum(p_t * (logp_t - logq), axis=-1)
    ent = -jnp.sum(p_t * logp_t, axis=-1)
    return ce, kl, ent, logp_a


# ----------------------------------------------------------------------------
# Composite DVI loss (paper eq. in §3.4) built on fused_losses; used both by
# the reference train step and by tests of the exported train_step artifact.
#   L = lam_pg * PG_masked + lam_kl * KL + w_ce * CE_masked - w_ent * H
# PG/CE averaged over accepted positions only; KL/H over all logged rows.
# ----------------------------------------------------------------------------

def dvi_loss(logits_theta, logits_phi, actions, rewards, mask,
             lam_pg, lam_kl, w_ce, w_ent, tau, w_rl, baseline):
    ce, kl, ent, logp_a = fused_losses(logits_theta, logits_phi, actions, tau)
    mask = mask.astype(logits_theta.dtype)
    rewards = rewards.astype(logits_theta.dtype)
    acc = mask * rewards                         # accepted rows
    n_acc = jnp.maximum(acc.sum(), 1.0)
    n_all = jnp.maximum(mask.sum(), 1.0)
    # Reward-masked CE on accepted rows (paper's L_pg "reward-masked term").
    l_pg = (acc * ce).sum() / n_acc
    l_kl = (mask * kl).sum() / n_all
    l_ce = (acc * ce).sum() / n_acc
    l_ent = (mask * ent).sum() / n_all
    # On-policy REINFORCE with EMA baseline over accepted + first-reject rows.
    adv = rewards - baseline
    l_rl = -(mask * adv * logp_a).sum() / n_all
    total = (lam_pg * l_pg + lam_kl * l_kl + w_ce * l_ce
             - w_ent * l_ent + w_rl * l_rl)
    metrics = jnp.stack([total, l_pg, l_kl, l_ce, l_ent, l_rl,
                         acc.sum() / n_all])
    return total, metrics
