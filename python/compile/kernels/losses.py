"""L1 Pallas kernel: fused per-example loss statistics, fwd + custom VJP.

For drafter logits z_t [N, V], verifier logits z_p [N, V], actions a [N]:

    ce   = -log p_theta(a)
    kl   = KL(p_theta || softmax(z_p / tau))
    ent  = H[p_theta]
    logp = log p_theta(a)

All four share the same softmax statistics, so the kernel computes each
row's log-softmax (for both distributions) ONCE and emits the four scalars
in a single pass — the fusion the composite DVI objective (paper §3.4)
wants on every optimizer step. The backward pass uses the closed forms

    d ce  /dz_t =  p - onehot(a)
    d kl  /dz_t =  p * (logp - logq - kl)
    d ent /dz_t = -p * (logp_row + ent)
    d logp/dz_t =  onehot(a) - p
    d kl  /dz_p =  (q - p) / tau

in a second single-pass kernel, avoiding softmax recomputation via saved
row statistics.

Grid = row tiles (N_TILE rows per step); V fits a single VMEM block at this
scale (512 f32 columns). interpret=True throughout (CPU PJRT).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

N_TILE = 8


def _row_logsoftmax(z):
    m = z.max(axis=-1, keepdims=True)
    lse = jnp.log(jnp.exp(z - m).sum(axis=-1, keepdims=True)) + m
    return z - lse


def _fwd_kernel(zt_ref, zp_ref, a_ref, ce_ref, kl_ref, ent_ref, logp_ref,
                *, tau: float):
    zt = zt_ref[...]                             # [T, V]
    zp = zp_ref[...] / tau
    a = a_ref[...]                               # [T]
    t, v = zt.shape
    logp = _row_logsoftmax(zt)
    logq = _row_logsoftmax(zp)
    p = jnp.exp(logp)
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (t, v), 1)
              == a[:, None]).astype(zt.dtype)
    logp_a = (onehot * logp).sum(axis=-1)
    ce_ref[...] = -logp_a
    kl_ref[...] = (p * (logp - logq)).sum(axis=-1)
    ent_ref[...] = -(p * logp).sum(axis=-1)
    logp_ref[...] = logp_a


def _bwd_kernel(zt_ref, zp_ref, a_ref, gce_ref, gkl_ref, gent_ref, glogp_ref,
                dzt_ref, dzp_ref, *, tau: float):
    zt = zt_ref[...]
    zp = zp_ref[...] / tau
    a = a_ref[...]
    t, v = zt.shape
    logp = _row_logsoftmax(zt)
    logq = _row_logsoftmax(zp)
    p = jnp.exp(logp)
    q = jnp.exp(logq)
    onehot = (jax.lax.broadcasted_iota(jnp.int32, (t, v), 1)
              == a[:, None]).astype(zt.dtype)
    kl = (p * (logp - logq)).sum(axis=-1, keepdims=True)
    ent = -(p * logp).sum(axis=-1, keepdims=True)
    gce = gce_ref[...][:, None]
    gkl = gkl_ref[...][:, None]
    gent = gent_ref[...][:, None]
    glogp = glogp_ref[...][:, None]
    dzt = (gce * (p - onehot)
           + gkl * p * (logp - logq - kl)
           + gent * (-p) * (logp + ent)
           + glogp * (onehot - p))
    dzp = gkl * (q - p) / tau
    dzt_ref[...] = dzt
    dzp_ref[...] = dzp


def _pallas_fwd(zt, zp, a, tau: float):
    n, v = zt.shape
    assert n % N_TILE == 0, f"rows {n} must be a multiple of {N_TILE}"
    grid = (n // N_TILE,)
    return pl.pallas_call(
        functools.partial(_fwd_kernel, tau=tau),
        grid=grid,
        in_specs=[
            pl.BlockSpec((N_TILE, v), lambda i: (i, 0)),
            pl.BlockSpec((N_TILE, v), lambda i: (i, 0)),
            pl.BlockSpec((N_TILE,), lambda i: (i,)),
        ],
        out_specs=[pl.BlockSpec((N_TILE,), lambda i: (i,))] * 4,
        out_shape=[jax.ShapeDtypeStruct((n,), zt.dtype)] * 4,
        interpret=True,
    )(zt, zp, a)


def _pallas_bwd(zt, zp, a, gce, gkl, gent, glogp, tau: float):
    n, v = zt.shape
    grid = (n // N_TILE,)
    return pl.pallas_call(
        functools.partial(_bwd_kernel, tau=tau),
        grid=grid,
        in_specs=[
            pl.BlockSpec((N_TILE, v), lambda i: (i, 0)),
            pl.BlockSpec((N_TILE, v), lambda i: (i, 0)),
            pl.BlockSpec((N_TILE,), lambda i: (i,)),
            pl.BlockSpec((N_TILE,), lambda i: (i,)),
            pl.BlockSpec((N_TILE,), lambda i: (i,)),
            pl.BlockSpec((N_TILE,), lambda i: (i,)),
            pl.BlockSpec((N_TILE,), lambda i: (i,)),
        ],
        out_specs=[
            pl.BlockSpec((N_TILE, v), lambda i: (i, 0)),
            pl.BlockSpec((N_TILE, v), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n, v), zt.dtype),
            jax.ShapeDtypeStruct((n, v), zt.dtype),
        ],
        interpret=True,
    )(zt, zp, a, gce, gkl, gent, glogp)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def fused_losses(logits_theta, logits_phi, actions, tau: float):
    """Per-example (ce, kl, ent, logp) — see module docstring."""
    return _pallas_fwd(logits_theta, logits_phi, actions, tau)


def _vjp_fwd(logits_theta, logits_phi, actions, tau: float):
    out = _pallas_fwd(logits_theta, logits_phi, actions, tau)
    return out, (logits_theta, logits_phi, actions)


def _vjp_bwd(tau: float, res, g):
    zt, zp, a = res
    gce, gkl, gent, glogp = g
    dzt, dzp = _pallas_bwd(zt, zp, a, gce, gkl, gent, glogp, tau)
    return dzt, dzp, None


fused_losses.defvjp(_vjp_fwd, _vjp_bwd)
