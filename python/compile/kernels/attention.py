"""L1 Pallas kernel: masked decode attention over a KV cache (online softmax).

Computes, for a block of Bq query positions starting at `pos`, attention
over an S-slot KV cache where query i may only attend to slots j <= pos+i.
Slots beyond the mask may contain *stale speculative garbage* (the Rust
coordinator rolls speculation back by decrementing positions, not by
clearing cache lines), so masking is a correctness requirement, not an
optimization.

TPU mapping (DESIGN.md §Hardware-Adaptation): this plays the role the
paper's serving stack delegates to fused GPU decode-attention. Grid =
(heads, S/S_TILE); KV tiles stream HBM->VMEM (BlockSpec), with the classic
online-softmax running statistics (max, denominator, weighted accumulator)
carried across KV tiles — the TPU analogue of a threadblock marching over
shared-memory tiles. Single pass over the cache. The running statistics
live in output refs mapped to the same block for every KV tile (the
portable Pallas accumulation idiom, equivalent to VMEM scratch on TPU).

interpret=True for CPU-PJRT executability; block shapes are TPU-shaped
(S_TILE=64 keys) so the kernel lifts to Mosaic unchanged.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

S_TILE = 64
NEG_INF = -1e30


def _attn_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref,
                 *, s_tile: int):
    t = pl.program_id(1)                         # KV tile index

    @pl.when(t == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...]                               # [Bq, hd] (one head)
    k = k_ref[...]                               # [S_TILE, hd]
    v = v_ref[...]                               # [S_TILE, hd]
    bq, hd = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, dtype=q.dtype))
    s = (q @ k.T) * scale                        # [Bq, S_TILE]

    pos = pos_ref[0]
    j = t * s_tile + jax.lax.broadcasted_iota(jnp.int32, (bq, s_tile), 1)
    i = jax.lax.broadcasted_iota(jnp.int32, (bq, s_tile), 0)
    s = jnp.where(j <= pos + i, s, NEG_INF)

    m_prev = m_ref[...]                          # [Bq, 1]
    m_cur = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    correction = jnp.exp(m_prev - m_cur)
    p = jnp.exp(s - m_cur)                       # [Bq, S_TILE]
    l_ref[...] = l_ref[...] * correction + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * correction + p @ v
    m_ref[...] = m_cur

    @pl.when(t == pl.num_programs(1) - 1)
    def _finalize():
        o_ref[...] = acc_ref[...] / l_ref[...]


def decode_attention(q, k_cache, v_cache, pos):
    """q [Bq, H, hd], caches [S, H, hd], pos scalar int32 -> [Bq, H, hd]."""
    bq, h, hd = q.shape
    s = k_cache.shape[0]
    assert s % S_TILE == 0, f"cache {s} must be a multiple of {S_TILE}"
    pos = jnp.asarray(pos, jnp.int32).reshape((1,))
    grid = (h, s // S_TILE)
    out, _m, _l, _acc = pl.pallas_call(
        functools.partial(_attn_kernel, s_tile=S_TILE),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda hh, t: (0,)),                 # pos
            pl.BlockSpec((bq, None, hd), lambda hh, t: (0, hh, 0)),  # q
            pl.BlockSpec((S_TILE, None, hd), lambda hh, t: (t, hh, 0)),
            pl.BlockSpec((S_TILE, None, hd), lambda hh, t: (t, hh, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bq, None, hd), lambda hh, t: (0, hh, 0)),  # o
            pl.BlockSpec((bq, None, 1), lambda hh, t: (0, hh, 0)),   # m
            pl.BlockSpec((bq, None, 1), lambda hh, t: (0, hh, 0)),   # l
            pl.BlockSpec((bq, None, hd), lambda hh, t: (0, hh, 0)),  # acc
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bq, h, hd), q.dtype),
            jax.ShapeDtypeStruct((bq, h, 1), q.dtype),
            jax.ShapeDtypeStruct((bq, h, 1), q.dtype),
            jax.ShapeDtypeStruct((bq, h, hd), q.dtype),
        ],
        interpret=True,
    )(pos, q, k_cache, v_cache)
    return out
