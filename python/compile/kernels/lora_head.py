"""L1 Pallas kernel: fused LoRA draft-head projection, fwd + custom VJP.

    logits = h @ (W + gamma * A @ B)^T
           = h @ W^T + gamma * (h @ B^T) @ A^T

TPU mapping (DESIGN.md §Hardware-Adaptation): the kernel makes ONE pass
over the vocabulary dimension. Grid = vocab tiles; for each tile the MXU
computes `h @ W_tile^T` and the rank-r correction `z @ A_tile^T` is fused
into the same output tile, where `z = h @ B^T` is recomputed per tile
(r << d, so the recompute is ~r/V of the main matmul — cheaper than an
HBM round-trip for z on real hardware, and it keeps the kernel single-pass).

Backward splits into:
  dA_tile = gamma * g_tile^T @ z          (Pallas, same vocab-tile grid)
  dz      = gamma * sum_tiles g_tile @ A_tile   (Pallas, accumulated)
  dB      = dz^T @ h                      (jnp; [r,d] is tiny)
  dh      = g @ W + dz @ B                (jnp; h carries no trainable grad
                                           in DVI but the vjp is complete)

Everything runs under interpret=True (CPU PJRT cannot execute Mosaic
custom-calls); on-TPU block shapes are chosen for MXU/VMEM anyway so the
kernel is lift-and-shift: V tiles of 128 rows x d columns.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

V_TILE = 128


def _fwd_kernel(h_ref, w_ref, a_ref, b_ref, o_ref, *, gamma: float):
    h = h_ref[...]                    # [N, d]
    z = h @ b_ref[...].T              # [N, r]   recomputed per tile (r small)
    o_ref[...] = h @ w_ref[...].T + gamma * (z @ a_ref[...].T)


def _da_kernel(g_ref, z_ref, da_ref, *, gamma: float):
    # dA_tile = gamma * g_tile^T @ z     [V_TILE, r]
    da_ref[...] = gamma * g_ref[...].T @ z_ref[...]


def _dz_kernel(g_ref, a_ref, dz_ref, *, gamma: float):
    # Accumulate dz += gamma * g_tile @ A_tile over the vocab grid.
    @pl.when(pl.program_id(0) == 0)
    def _init():
        dz_ref[...] = jnp.zeros_like(dz_ref)

    dz_ref[...] += gamma * g_ref[...] @ a_ref[...]


def _pallas_fwd(h, w, a, b, gamma: float):
    n, d = h.shape
    v = w.shape[0]
    r = a.shape[1]
    assert v % V_TILE == 0, f"vocab {v} must be a multiple of {V_TILE}"
    grid = (v // V_TILE,)
    return pl.pallas_call(
        functools.partial(_fwd_kernel, gamma=gamma),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, d), lambda i: (0, 0)),
            pl.BlockSpec((V_TILE, d), lambda i: (i, 0)),
            pl.BlockSpec((V_TILE, r), lambda i: (i, 0)),
            pl.BlockSpec((r, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((n, V_TILE), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n, v), h.dtype),
        interpret=True,
    )(h, w, a, b)


def _pallas_da(g, z, gamma: float):
    n, v = g.shape
    r = z.shape[1]
    grid = (v // V_TILE,)
    return pl.pallas_call(
        functools.partial(_da_kernel, gamma=gamma),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, V_TILE), lambda i: (0, i)),
            pl.BlockSpec((n, r), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((V_TILE, r), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((v, r), g.dtype),
        interpret=True,
    )(g, z)


def _pallas_dz(g, a, gamma: float):
    n, v = g.shape
    r = a.shape[1]
    grid = (v // V_TILE,)
    return pl.pallas_call(
        functools.partial(_dz_kernel, gamma=gamma),
        grid=grid,
        in_specs=[
            pl.BlockSpec((n, V_TILE), lambda i: (0, i)),
            pl.BlockSpec((V_TILE, r), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((n, r), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, r), g.dtype),
        interpret=True,
    )(g, a)


@functools.partial(jax.custom_vjp, nondiff_argnums=(4,))
def lora_head(h, w, a, b, gamma: float):
    """Fused LoRA head logits [N, V]. Differentiable wrt h, a, b (w frozen)."""
    return _pallas_fwd(h, w, a, b, gamma)


def _vjp_fwd(h, w, a, b, gamma: float):
    out = _pallas_fwd(h, w, a, b, gamma)
    return out, (h, w, a, b)


def _vjp_bwd(gamma: float, res, g):
    h, w, a, b = res
    z = h @ b.T                        # [N, r]
    da = _pallas_da(g, z, gamma)       # [V, r]
    dz = _pallas_dz(g, a, gamma)       # [N, r]
    db = dz.T @ h                      # [r, d]
    dh = g @ w + dz @ b                # [N, d]
    dw = jnp.zeros_like(w)             # frozen base projection
    return dh, dw, da, db


lora_head.defvjp(_vjp_fwd, _vjp_bwd)
