"""Backbone pretraining on the synthetic corpus (build path only).

Trains the full llama-style backbone with Adam + cosine decay on packed
LM batches from `corpus.token_stream`. The trained weights are the
"Vicuna-7B analogue" of this reproduction (DESIGN.md §Substitutions): a
model that has genuinely *learned* the language, so the shallow/deep
representation gap that drives DVI's online-learning dynamics is real.

Outputs `artifacts/backbone.npz` (plus a loss log in
`artifacts/pretrain_log.csv`). Run via `make artifacts` — cached, never on
the request path.

Usage: python -m compile.pretrain [--steps N] [--out PATH]
"""

from __future__ import annotations

import argparse
import math
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from . import corpus
from .config import DEFAULT_MODEL, DEFAULT_PRETRAIN, ModelConfig, PretrainConfig
from . import model as M


def lm_loss(params, tokens, cfg: ModelConfig):
    """Mean next-token CE over a packed batch [B, T+1]."""
    logits = M.forward_train(params, tokens[:, :-1], cfg)
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return -ll.mean()


def adam_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params)}


def adam_update(params, grads, opt, lr, b1=0.9, b2=0.999, eps=1e-8, t=1):
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, opt["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, opt["v"], grads)
    mhat = jax.tree.map(lambda x: x / (1 - b1 ** t), m)
    vhat = jax.tree.map(lambda x: x / (1 - b2 ** t), v)
    params = jax.tree.map(
        lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mhat, vhat
    )
    return params, {"m": m, "v": v}


def lr_schedule(step: int, cfg: PretrainConfig) -> float:
    if step < cfg.warmup:
        return cfg.lr * (step + 1) / cfg.warmup
    frac = (step - cfg.warmup) / max(1, cfg.steps - cfg.warmup)
    return cfg.lr * 0.5 * (1 + math.cos(math.pi * frac))


def pretrain(mcfg: ModelConfig, pcfg: PretrainConfig, out_path: str,
             log_path: str | None = None) -> dict:
    key = jax.random.PRNGKey(pcfg.seed)
    params = M.init_params(mcfg, key)
    opt = adam_init(params)

    n_tok = pcfg.steps * pcfg.batch_size * (pcfg.seq_len + 1)
    stream = np.asarray(
        corpus.token_stream(corpus.PRETRAIN_SEED, n_tok), dtype=np.int32
    ).reshape(pcfg.steps, pcfg.batch_size, pcfg.seq_len + 1)

    @jax.jit
    def step_fn(params, opt, batch, lr, t):
        loss, grads = jax.value_and_grad(lm_loss)(params, batch, mcfg)
        params, opt = adam_update(params, grads, opt, lr, t=t)
        return params, opt, loss

    log: list[tuple[int, float]] = []
    t0 = time.time()
    for step in range(pcfg.steps):
        lr = lr_schedule(step, pcfg)
        params, opt, loss = step_fn(params, opt, stream[step],
                                    jnp.float32(lr), step + 1)
        if step % 25 == 0 or step == pcfg.steps - 1:
            loss_f = float(loss)
            log.append((step, loss_f))
            dt = time.time() - t0
            print(f"step {step:5d} loss {loss_f:.4f} "
                  f"({dt:.0f}s, {dt / (step + 1):.2f}s/step)", flush=True)

    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    np.savez(out_path, **{k: np.asarray(v) for k, v in params.items()})
    if log_path:
        with open(log_path, "w") as f:
            f.write("step,loss\n")
            for s, l in log:
                f.write(f"{s},{l:.6f}\n")
    print(f"saved {out_path}")
    return params


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=DEFAULT_PRETRAIN.steps)
    ap.add_argument("--out", default="../artifacts/backbone.npz")
    ap.add_argument("--log", default="../artifacts/pretrain_log.csv")
    args = ap.parse_args()
    pcfg = PretrainConfig(steps=args.steps)
    pretrain(DEFAULT_MODEL, pcfg, args.out, args.log)


if __name__ == "__main__":
    main()
