"""Golden test vectors for Rust<->Python numerics parity.

For each core artifact, runs the *Python* function on deterministic inputs
and dumps inputs + expected outputs as named tensors (weights.bin format)
into `artifacts/testvecs.bin`. The Rust integration test
(`rust/tests/parity.rs`) executes the compiled HLO with the same inputs and
asserts allclose — proving the whole AOT bridge (lowering, text round-trip,
PJRT compile, buffer plumbing, manifest ordering) end to end.

Naming: `<artifact>.<in|out>.<port_name>` (+ ".N" for repeated KV ports).

Usage: python -m compile.testvec --out ../artifacts/testvecs.bin
"""

from __future__ import annotations

import argparse
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from . import aot
from .aot import ARTIFACTS, _spec, write_weights_bin
from .config import DEFAULT_MODEL

CFG = DEFAULT_MODEL

# Artifacts worth a golden vector (cover every port-role combination).
COVER = [
    "draft_step", "draft_block", "verify_block", "train_step", "prefill_shallow",
    "prefill_deep", "target_step", "target_verify_block", "prefill_full",
    "medusa_heads", "hydra_chain", "eagle_step",
]


def _gen_input(port, rng, tensors):
    """Deterministic input for a port. Weight/global ports read the real
    tensor from weights.bin content so the vector matches serving."""
    if port.role == "weight":
        return jnp.asarray(tensors[port.name])
    if port.role == "global":
        # Use small random values (NOT the init tensors: lora.A inits to
        # zero, which would leave the LoRA path untested).
        shape = tuple(port.shape)
        return jnp.asarray(rng.normal(size=shape) * 0.05, jnp.float32)
    shape = tuple(port.shape)
    if port.dtype == "i32":
        if port.name in ("tok", "tok0"):
            return jnp.asarray(rng.integers(6, CFG.vocab_size), jnp.int32)
        if port.name == "pos":
            return jnp.asarray(17, jnp.int32)
        if port.name == "length":
            return jnp.asarray(11, jnp.int32)
        if port.name in ("tokens", "toks"):
            arr = rng.integers(6, CFG.vocab_size, size=shape)
            return jnp.asarray(arr, jnp.int32)
        if port.name == "actions":
            return jnp.asarray(rng.integers(0, CFG.vocab_size, size=shape),
                               jnp.int32)
        return jnp.asarray(np.zeros(shape), jnp.int32)
    if port.name == "hyper":
        # lam_pg, lam_kl, w_ce, w_ent, w_rl, baseline, lr, step
        return jnp.asarray([0.5, 1.0, 0.5, 0.01, 0.5, 0.6, 1e-3, 3.0],
                           jnp.float32)
    if port.name in ("rewards", "mask"):
        return jnp.asarray(rng.integers(0, 2, size=shape), jnp.float32)
    scale = 0.5 if port.name.startswith(("hk", "hl", "feat")) else 0.3
    return jnp.asarray(rng.normal(size=shape) * scale, jnp.float32)


def build_testvecs(tensors: dict) -> dict:
    out = {}
    for name in COVER:
        if name not in ARTIFACTS:
            continue
        fn, ports, outs = ARTIFACTS[name]()
        if any(p.role in ("weight", "global") and p.name not in tensors
               for p in ports):
            print(f"  skip {name} (missing weights)")
            continue
        rng = np.random.default_rng(zlib.crc32(name.encode()))
        args = [_gen_input(p, rng, tensors) for p in ports]
        results = jax.jit(fn)(*args)
        for p, a in zip(ports, args):
            if p.role in ("in", "kv", "global"):
                out[f"{name}.in.{p.name}"] = np.asarray(a)
        for o, r in zip(outs, results):
            out[f"{name}.out.{o.name}"] = np.asarray(r)
        print(f"  testvec {name}: {len(ports)} in, {len(outs)} out")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts/testvecs.bin")
    ap.add_argument("--backbone", default="../artifacts/backbone.npz")
    ap.add_argument("--heads", default="../artifacts/heads.npz")
    args = ap.parse_args()

    import os
    params = {k: jnp.asarray(v) for k, v in np.load(args.backbone).items()}
    tensors = aot.split_weights(params)
    if os.path.exists(args.heads):
        tensors.update({k: np.asarray(v)
                        for k, v in np.load(args.heads).items()})
    lora = __import__("compile.model", fromlist=["init_lora"]).init_lora(
        CFG, jax.random.PRNGKey(42))
    tensors["lora.A"] = np.asarray(lora["A"])
    tensors["lora.B"] = np.asarray(lora["B"])
    for n, ref in (("adam.mA", "lora.A"), ("adam.vA", "lora.A"),
                   ("adam.mB", "lora.B"), ("adam.vB", "lora.B")):
        tensors[n] = np.zeros_like(tensors[ref])

    vecs = build_testvecs(tensors)
    write_weights_bin(args.out, vecs)
    print(f"wrote {len(vecs)} tensors -> {args.out}")


if __name__ == "__main__":
    main()
