"""Model / training configuration shared by the whole compile path.

The single source of truth for shapes: `aot.py` serializes the relevant
fields into `artifacts/manifest.json`, and the Rust runtime reads shapes
from the manifest — nothing on the Rust side hard-codes model dimensions.

The backbone is a deliberately small llama-style model ("vicuna-sim", see
DESIGN.md §Substitutions): the paper's dynamics depend on the relationship
between shallow and deep representations of a *trained* LM, which this
model reproduces at CPU-friendly scale.
"""

from dataclasses import dataclass, field, asdict


@dataclass(frozen=True)
class ModelConfig:
    vocab_size: int = 512
    d_model: int = 192
    n_layers: int = 10
    n_heads: int = 6          # head_dim = 32
    d_ff: int = 512           # SwiGLU inner width
    max_seq: int = 320        # KV-cache capacity (prompt + generation)
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5

    # Self-speculative split (paper: k=2 of 32; here k=2 of 10).
    split_layer: int = 2

    # LoRA draft head (paper §3.1): logits_theta = (W_S + gamma * A @ B) h_k.
    # rank 64 measured at 0.77 teacher-forced agreement vs 0.74 @ rank 32
    # (EXPERIMENTS.md §Calibration); the paper's plateau story needs the
    # higher ceiling.
    lora_rank: int = 64
    lora_gamma: float = 2.0

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def deep_layers(self) -> int:
        return self.n_layers - self.split_layer


@dataclass(frozen=True)
class SpecConfig:
    """Speculation geometry, mirrored by the Rust engines."""
    k_spec: int = 4            # proposal depth (paper: k_spec = 4)
    prefill_seq: int = 192     # padded prompt length for prefill artifacts
    max_new_tokens: int = 96


@dataclass(frozen=True)
class TrainConfig:
    """Online DVI training (L2 train_step artifact + Rust learner)."""
    batch_size: int = 64       # replay-buffer minibatch (N)
    adam_b1: float = 0.9
    adam_b2: float = 0.999
    adam_eps: float = 1e-8
    # 3e-3 reaches the KD agreement ceiling within the paper's 2k-step
    # budget; 1e-3 visibly undershoots (EXPERIMENTS.md §Calibration).
    lr: float = 3e-3
    # KL -> RL schedule defaults (overridable from the Rust CLI; these are
    # the values baked into configs/, not into the HLO).
    t_warmup: int = 300
    t_ramp: int = 600
    lam_kl0: float = 1.0
    lam_kl_min: float = 0.2
    lam_pg_max: float = 1.0
    w_ce: float = 0.5
    w_ent: float = 0.01
    w_rl: float = 0.5
    kd_tau: float = 1.0


@dataclass(frozen=True)
class PretrainConfig:
    steps: int = 1500
    batch_size: int = 16
    seq_len: int = 96
    lr: float = 3e-3
    warmup: int = 100
    seed: int = 0


DEFAULT_MODEL = ModelConfig()
DEFAULT_SPEC = SpecConfig()
DEFAULT_TRAIN = TrainConfig()
DEFAULT_PRETRAIN = PretrainConfig()


def config_dict() -> dict:
    return {
        "model": asdict(DEFAULT_MODEL),
        "spec": asdict(DEFAULT_SPEC),
        "train": asdict(DEFAULT_TRAIN),
        "pretrain": asdict(DEFAULT_PRETRAIN),
    }
