"""L2 DVI online-training step — exported as the `train_step` HLO artifact.

This is the paper's §3.4 composite objective with the KL->RL schedule
*weights as runtime inputs* (the Rust learner anneals them; the HLO is
schedule-agnostic):

    L = lam_pg * L_pg + lam_kl * KL(p_theta || p_phi^tau)
        + w_ce * L_CE - w_ent * H[p_theta] + w_rl * L_policy

  * L_pg / L_CE: reward-masked CE on accepted rows only (censored rows —
    anything past the first reject — never reach the buffer; the Rust
    side enforces that and `mask` re-enforces it here).
  * KL / H: over all logged rows (accepted + first reject).
  * L_policy: on-policy REINFORCE with an EMA-baseline advantage
    (r - b) * log p_theta(a), over all logged rows.

Gradients flow only into the LoRA adapters (A, B) — through the L1 Pallas
kernels `lora_head` and `fused_losses`, both of which carry custom VJPs.
The Adam update (bias-corrected) is fused into the same artifact so one
PJRT call performs the whole optimizer step; A/B/moments are chained
device-resident buffers on the Rust side.

Hyper vector layout (f32[8], also in manifest):
    [0] lam_pg  [1] lam_kl  [2] w_ce  [3] w_ent
    [4] w_rl    [5] baseline  [6] lr  [7] step (t >= 1, for bias correction)

Metrics vector layout (f32[8]):
    [0] total  [1] l_pg  [2] l_kl  [3] l_ce  [4] l_ent  [5] l_rl
    [6] batch acceptance rate  [7] grad l2-norm
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig, TrainConfig
from .kernels.losses import fused_losses
from . import model as M

HYPER_LEN = 8
METRICS_LEN = 8


def dvi_loss(logits_theta, logits_phi, actions, rewards, mask, hyper,
             tau: float):
    """Composite DVI objective; mirrors kernels.ref.dvi_loss (the oracle)
    but routes the per-example statistics through the Pallas kernel."""
    ce, kl, ent, logp_a = fused_losses(
        logits_theta, jax.lax.stop_gradient(logits_phi), actions, tau)
    lam_pg, lam_kl, w_ce, w_ent, w_rl, baseline = (
        hyper[0], hyper[1], hyper[2], hyper[3], hyper[4], hyper[5])
    mask = mask.astype(logits_theta.dtype)
    rewards = rewards.astype(logits_theta.dtype)
    acc = mask * rewards
    n_acc = jnp.maximum(acc.sum(), 1.0)
    n_all = jnp.maximum(mask.sum(), 1.0)
    l_pg = (acc * ce).sum() / n_acc          # reward-masked CE (paper L_pg)
    l_kl = (mask * kl).sum() / n_all
    l_ce = (acc * ce).sum() / n_acc
    l_ent = (mask * ent).sum() / n_all
    adv = rewards - baseline
    l_rl = -(mask * adv * logp_a).sum() / n_all
    total = (lam_pg * l_pg + lam_kl * l_kl + w_ce * l_ce
             - w_ent * l_ent + w_rl * l_rl)
    parts = jnp.stack([total, l_pg, l_kl, l_ce, l_ent, l_rl,
                       acc.sum() / n_all])
    return total, parts


def train_step(frozen, lora_a, lora_b, m_a, v_a, m_b, v_b,
               hk, actions, logits_phi, rewards, mask, hyper,
               mcfg: ModelConfig, tcfg: TrainConfig):
    """One fused loss+grad+Adam step. `frozen` = dict with draft_base,
    final_norm (weight-role params). Returns
    (lora_a', lora_b', m_a', v_a', m_b', v_b', metrics)."""

    def loss_fn(ab):
        a, b = ab
        logits_theta = M.draft_head_logits(frozen, a, b, hk, mcfg)
        return dvi_loss(logits_theta, logits_phi, actions, rewards, mask,
                        hyper, tcfg.kd_tau)

    (_, parts), grads = jax.value_and_grad(loss_fn, has_aux=True)(
        (lora_a, lora_b))
    ga, gb = grads
    gnorm = jnp.sqrt((ga * ga).sum() + (gb * gb).sum())

    lr, t = hyper[6], hyper[7]
    b1, b2, eps = tcfg.adam_b1, tcfg.adam_b2, tcfg.adam_eps

    def adam(p, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / (1 - b1 ** t)
        vhat = v / (1 - b2 ** t)
        return p - lr * mhat / (jnp.sqrt(vhat) + eps), m, v

    lora_a, m_a, v_a = adam(lora_a, ga, m_a, v_a)
    lora_b, m_b, v_b = adam(lora_b, gb, m_b, v_b)

    metrics = jnp.concatenate([parts, gnorm[None]])
    return lora_a, lora_b, m_a, v_a, m_b, v_b, metrics
