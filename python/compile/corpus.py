"""Synthetic language + workload generators.

This module is the data substrate that replaces ShareGPT (training stream)
and Spec-Bench (six evaluation workloads) — see DESIGN.md §Substitutions.

Design goals:
  * a 512-token vocabulary shared between Python (pretraining/AOT) and the
    Rust coordinator (tokenizer + workloads read `artifacts/vocab.json` /
    `artifacts/prompts/*.bin`);
  * a language a ~5M-param model learns to low perplexity in ~1.5k steps;
  * six task flavours whose *distributional signatures* match the axes that
    drive the paper's per-task results (local lexical structure, copy rate,
    long-range dependence — see DESIGN.md).

Everything is deterministic given a seed; eval prompt sets use held-out
seeds so online training never sees the benchmark prompts.
"""

from __future__ import annotations

import random
from dataclasses import dataclass

# ----------------------------------------------------------------------------
# Vocabulary (512 tokens)
# ----------------------------------------------------------------------------

PAD, BOS, EOS, SEP, USR, ASST = 0, 1, 2, 3, 4, 5

_SPECIALS = ["<pad>", "<bos>", "<eos>", "<sep>", "<usr>", "<asst>"]
_DIGITS = [str(d) for d in range(10)]
_PUNCT = ["+", "-", "*", "=", "(", ")", ".", ",", "?", "!", ":", ";"]
_CONTROL = [
    "translate", "summarize", "question", "answer", "context", "compute",
    "what", "who", "the", "is", "of", "and", "to", "a", "in", "it",
    "please", "tell", "me", "about", "hello", "thanks", "yes", "no",
]

_N_ENTITIES = 48
_N_RELATIONS = 8
_N_VERBS = 24
_N_ADJ = 24
_N_NOUNS = 40
_N_MAPPABLE = 100   # base words with a foreign-token translation

_ENTITIES = [f"ent{i:02d}" for i in range(_N_ENTITIES)]
_RELATIONS = [
    "owns", "likes", "visits", "knows", "leads", "follows", "builds", "sells",
]
assert len(_RELATIONS) == _N_RELATIONS
_VERBS = [f"verb{i:02d}" for i in range(_N_VERBS)]
_ADJ = [f"adj{i:02d}" for i in range(_N_ADJ)]
_NOUNS = [f"noun{i:02d}" for i in range(_N_NOUNS)]
_FOREIGN = [f"g{i:03d}" for i in range(_N_MAPPABLE)]


def build_vocab() -> list[str]:
    """Token id -> string. Padded with filler words to exactly 512."""
    words = (
        _SPECIALS + _DIGITS + _PUNCT + _CONTROL
        + _ENTITIES + _RELATIONS + _VERBS + _ADJ + _NOUNS + _FOREIGN
    )
    i = 0
    while len(words) < 512:
        words.append(f"fill{i:03d}")
        i += 1
    assert len(words) == 512, len(words)
    assert len(set(words)) == 512
    return words


VOCAB = build_vocab()
TOK = {w: i for i, w in enumerate(VOCAB)}


def encode(words: list[str]) -> list[int]:
    return [TOK[w] for w in words]


def decode(ids: list[int]) -> list[str]:
    return [VOCAB[i] for i in ids]


# Mappable words for the translation task: the first 100 "content" words.
_MAPPABLE = (_ENTITIES + _VERBS + _ADJ + _NOUNS)[:_N_MAPPABLE]
TRANSLATION = {w: g for w, g in zip(_MAPPABLE, _FOREIGN)}


# ----------------------------------------------------------------------------
# Knowledge base (deterministic): relation(entity) -> entity
# ----------------------------------------------------------------------------

def _kb() -> dict[tuple[str, str], str]:
    rng = random.Random(1337)
    kb = {}
    for e in _ENTITIES:
        for r in _RELATIONS:
            kb[(e, r)] = _ENTITIES[rng.randrange(_N_ENTITIES)]
    return kb


KB = _kb()


def _fact_words(e: str, r: str) -> list[str]:
    return [e, r, KB[(e, r)], "."]


# ----------------------------------------------------------------------------
# Task generators. Each returns (prompt_words, answer_words).
# Prompt ends with <sep>; answer ends with <eos>.
# ----------------------------------------------------------------------------

def gen_translation(rng: random.Random) -> tuple[list[str], list[str]]:
    n = rng.randint(4, 10)
    src = [rng.choice(_MAPPABLE) for _ in range(n)]
    tgt = [TRANSLATION[w] for w in src]
    return ["translate", ":"] + src + ["<sep>"], tgt + ["<eos>"]


def _digits_of(x: int) -> list[str]:
    return list(str(x))


def gen_math(rng: random.Random) -> tuple[list[str], list[str]]:
    a = rng.randint(10, 999)
    b = rng.randint(10, 999)
    op = rng.choice(["+", "-"])
    res = a + b if op == "+" else a - b
    ans = _digits_of(abs(res))
    if res < 0:
        ans = ["-"] + ans
    prompt = ["compute", ":"] + _digits_of(a) + [op] + _digits_of(b) + ["=", "<sep>"]
    return prompt, ans + ["<eos>"]


def gen_qa(rng: random.Random) -> tuple[list[str], list[str]]:
    e = rng.choice(_ENTITIES)
    r = rng.choice(_RELATIONS)
    prompt = ["question", ":", "what", r, e, "?", "<sep>"]
    return prompt, [KB[(e, r)], ".", "<eos>"]


def _doc_sentences(rng: random.Random, n: int) -> list[list[str]]:
    sents = []
    for _ in range(n):
        kind = rng.random()
        if kind < 0.5:
            e, r = rng.choice(_ENTITIES), rng.choice(_RELATIONS)
            sents.append(_fact_words(e, r))
        else:
            s = [
                "the", rng.choice(_ADJ), rng.choice(_NOUNS),
                rng.choice(_VERBS), "the", rng.choice(_NOUNS), ".",
            ]
            sents.append(s)
    return sents


def gen_summarization(rng: random.Random) -> tuple[list[str], list[str]]:
    sents = _doc_sentences(rng, rng.randint(4, 7))
    doc = [w for s in sents for w in s]
    # Extractive convention the model learns: the summary is the first
    # fact sentence (or the first sentence if no facts).
    summary = next((s for s in sents if s[1] in _RELATIONS), sents[0])
    return ["summarize", ":"] + doc + ["<sep>"], summary + ["<eos>"]


def gen_rag(rng: random.Random) -> tuple[list[str], list[str]]:
    # Retrieved context contains the answer; high copy-rate workload.
    e, r = rng.choice(_ENTITIES), rng.choice(_RELATIONS)
    chunks = [_fact_words(e, r)]
    for _ in range(rng.randint(2, 3)):
        e2, r2 = rng.choice(_ENTITIES), rng.choice(_RELATIONS)
        chunks.append(_fact_words(e2, r2))
    rng.shuffle(chunks)
    ctx = [w for c in chunks for w in c]
    prompt = ["context", ":"] + ctx + ["question", ":", "what", r, e, "?", "<sep>"]
    # Answer restates the full fact (copying from context).
    return prompt, [e, r, KB[(e, r)], ".", "<eos>"]


_GREETINGS = [
    ["hello", "please", "tell", "me", "about"],
    ["what", "is", "the"],
    ["please", "compute"],
]


def gen_chat(rng: random.Random) -> tuple[list[str], list[str]]:
    """Multi-turn assistant-flavoured dialogue (MT-Bench analogue)."""
    turns: list[str] = []
    n_turns = rng.randint(1, 2)
    answer: list[str] = []
    for t in range(n_turns):
        e = rng.choice(_ENTITIES)
        r = rng.choice(_RELATIONS)
        turns += ["<usr>"] + rng.choice(_GREETINGS) + [e, "?"]
        resp = [e, r, KB[(e, r)], ",", "and", e, rng.choice(_VERBS),
                "the", rng.choice(_NOUNS), "."]
        if t < n_turns - 1:
            turns += ["<asst>"] + resp
        else:
            turns += ["<sep>"]
            answer = resp + ["<eos>"]
    return turns, answer


TASKS = {
    "translation": gen_translation,
    "math": gen_math,
    "qa": gen_qa,
    "summarization": gen_summarization,
    "rag": gen_rag,
    "mt": gen_chat,
}

# Pretraining mixture: heavier on translation (local structure) so the
# backbone masters the deterministic tasks; mirrors an instruction-tuned
# LM being confident on templated continuations.
_PRETRAIN_MIX = [
    ("translation", 0.28),
    ("math", 0.14),
    ("qa", 0.14),
    ("rag", 0.16),
    ("summarization", 0.12),
    ("mt", 0.16),
]

# ShareGPT-analogue online stream: assistant-flavoured mixture (more chat /
# qa / rag), deliberately *not* identical to the eval task mixture.
_STREAM_MIX = [
    ("mt", 0.30),
    ("qa", 0.20),
    ("rag", 0.20),
    ("translation", 0.15),
    ("summarization", 0.10),
    ("math", 0.05),
]


def _pick(rng: random.Random, mix) -> str:
    x = rng.random()
    acc = 0.0
    for name, p in mix:
        acc += p
        if x < acc:
            return name
    return mix[-1][0]


@dataclass
class Sample:
    task: str
    prompt: list[int]    # token ids, starts with BOS, ends with SEP
    answer: list[int]    # token ids, ends with EOS


def make_sample(task: str, rng: random.Random) -> Sample:
    p, a = TASKS[task](rng)
    return Sample(task, [BOS] + encode(p), encode(a))


def pretrain_doc(rng: random.Random) -> list[int]:
    """One LM-training document: prompt + answer as a flat sequence."""
    s = make_sample(_pick(rng, _PRETRAIN_MIX), rng)
    return s.prompt + s.answer


def token_stream(seed: int, n_tokens: int) -> list[int]:
    """Concatenated documents, for fixed-length LM batch packing."""
    rng = random.Random(seed)
    out: list[int] = []
    while len(out) < n_tokens:
        out.extend(pretrain_doc(rng))
    return out[:n_tokens]


def eval_prompts(task: str, n: int, seed: int) -> list[Sample]:
    rng = random.Random(seed)
    return [make_sample(task, rng) for _ in range(n)]


def sharegpt_stream(n: int, seed: int) -> list[Sample]:
    rng = random.Random(seed)
    return [make_sample(_pick(rng, _STREAM_MIX), rng) for _ in range(n)]


# Seeds: pretraining uses 0xC0FFEE-range, the online stream uses 7000,
# eval prompt sets use 9000+task-index — all disjoint.
PRETRAIN_SEED = 0xC0FFEE
STREAM_SEED = 7000
EVAL_SEED_BASE = 9000
