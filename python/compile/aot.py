"""AOT exporter: lowers every decode/train computation to HLO text and
packages weights + manifest + vocab + prompt sets into `artifacts/`.

This is the ONLY bridge between Python and Rust. Python never runs on the
request path; the Rust coordinator loads:

  * `manifest.json`  — for each artifact: HLO file + ordered parameter and
    output descriptors {name, shape, dtype, role}. Roles drive the generic
    Rust runtime:
      weight  — immutable tensor from weights.bin, uploaded once
      global  — named mutable device buffer (LoRA adapters, Adam moments),
                updated in place when an output carries the same name
      kv      — per-sequence chained device buffer (caller-owned)
      in/out  — per-call host data
  * `weights.bin`    — named tensors (backbone split + baseline heads)
  * `vocab.json`     — token id -> string
  * `prompts/*.bin`  — token-id prompt sets (6 eval tasks + online stream)

Interchange is HLO *text* via mlir_module_to_xla_computation — the image's
xla_extension 0.5.1 rejects jax>=0.5 serialized protos (64-bit ids); the
text parser reassigns ids (see /opt/xla-example/README.md).

Usage: python -m compile.aot --out ../artifacts [--skip-train-heads]
"""

from __future__ import annotations

import argparse
import json
import os
import struct
import sys
import time
from dataclasses import dataclass, asdict

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import corpus
from . import model as M
from . import train as T
from .config import DEFAULT_MODEL, DEFAULT_SPEC, DEFAULT_TRAIN, config_dict
from .distill import (EAGLE_HIDDEN, HYDRA_HIDDEN, MEDUSA_HEADS, MEDUSA_HIDDEN,
                      SPS_CFG, medusa_logits, eagle_predict)

CFG = DEFAULT_MODEL
SPEC = DEFAULT_SPEC
TCFG = DEFAULT_TRAIN

F32, I32 = "f32", "i32"


@dataclass
class Port:
    name: str
    shape: tuple
    dtype: str
    role: str   # weight | global | kv | in | out


def _spec(p: Port):
    dt = jnp.float32 if p.dtype == F32 else jnp.int32
    return jax.ShapeDtypeStruct(tuple(p.shape), dt)


def to_hlo_text(fn, in_specs, donate=()) -> str:
    """Lower to HLO text. `donate` = parameter indices to mark donated —
    XLA then updates KV caches in place instead of copying the whole
    cache every call (input_output_alias survives the text round-trip;
    EXPERIMENTS.md §Perf). Only caller-owned per-sequence state (role=kv)
    is ever donated: `global` buffers are read concurrently by workers
    while the learner replaces them, so they must stay immutable."""
    lowered = jax.jit(fn, donate_argnums=tuple(donate)).lower(*in_specs)
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ----------------------------------------------------------------------------
# Weight naming: split the pretrained backbone into shallow/deep groups
# ----------------------------------------------------------------------------

def split_weights(params: dict) -> dict:
    k = CFG.split_layer
    w = {"sh.embed": params["embed"]}
    for t in M.LAYER_TENSORS:
        w[f"sh.{t}"] = params[t][:k]
        w[f"dp.{t}"] = params[t][k:]
    w["dp.final_norm"] = params["final_norm"]
    w["dp.lm_head"] = params["lm_head"]
    # Frozen draft-head base projection = transplanted LM head (paper §3.1).
    w["draft_base"] = params["lm_head"]
    # Full-model stacked tensors for the AR/baseline executables.
    w["fl.embed"] = params["embed"]
    for t in M.LAYER_TENSORS:
        w[f"fl.{t}"] = params[t]
    w["fl.final_norm"] = params["final_norm"]
    w["fl.lm_head"] = params["lm_head"]
    return w


def _shallow_ports() -> list:
    d, k = CFG.d_model, CFG.split_layer
    ff, V = CFG.d_ff, CFG.vocab_size
    shapes = {
        "wq": (k, d, d), "wk": (k, d, d), "wv": (k, d, d), "wo": (k, d, d),
        "w_gate": (k, d, ff), "w_up": (k, d, ff), "w_down": (k, ff, d),
        "rms_attn": (k, d), "rms_mlp": (k, d),
    }
    ports = [Port("sh.embed", (V, d), F32, "weight")]
    ports += [Port(f"sh.{t}", shapes[t], F32, "weight") for t in M.LAYER_TENSORS]
    return ports


def _deep_ports(prefix="dp", n=None, cfg=None) -> list:
    cfg = cfg or CFG
    n = n if n is not None else cfg.deep_layers
    d, ff = cfg.d_model, cfg.d_ff
    shapes = {
        "wq": (n, d, d), "wk": (n, d, d), "wv": (n, d, d), "wo": (n, d, d),
        "w_gate": (n, d, ff), "w_up": (n, d, ff), "w_down": (n, ff, d),
        "rms_attn": (n, d), "rms_mlp": (n, d),
    }
    return [Port(f"{prefix}.{t}", shapes[t], F32, "weight")
            for t in M.LAYER_TENSORS]


def _params_from(ports, args, prefix):
    """Rebuild a model.py-style params dict from flat artifact args."""
    out = {}
    for port, arr in zip(ports, args):
        if port.name.startswith(prefix + "."):
            out[port.name[len(prefix) + 1:]] = arr
    return out


def _kv_shape(n_layers):
    return (n_layers, CFG.max_seq, CFG.n_heads, CFG.head_dim)


# ----------------------------------------------------------------------------
# Artifact definitions
# ----------------------------------------------------------------------------

ARTIFACTS = {}


def artifact(name):
    def reg(build):
        ARTIFACTS[name] = build
        return build
    return reg


@artifact("draft_step")
def _draft_step():
    d, V, r = CFG.d_model, CFG.vocab_size, CFG.lora_rank
    k = CFG.split_layer
    ports = _shallow_ports() + [
        Port("dp.final_norm", (d,), F32, "weight"),
        Port("draft_base", (V, d), F32, "weight"),
        Port("lora.A", (V, r), F32, "global"),
        Port("lora.B", (r, d), F32, "global"),
        Port("kv_sh_k", _kv_shape(k), F32, "kv"),
        Port("kv_sh_v", _kv_shape(k), F32, "kv"),
        Port("tok", (), I32, "in"),
        Port("pos", (), I32, "in"),
    ]
    outs = [
        Port("logits_theta", (V,), F32, "out"),
        Port("hk", (d,), F32, "out"),
        Port("kv_sh_k", _kv_shape(k), F32, "kv"),
        Port("kv_sh_v", _kv_shape(k), F32, "kv"),
    ]

    def fn(*args):
        p = _params_from(ports, args, "sh")
        p["final_norm"] = args[10]
        p["draft_base"] = args[11]
        lora_a, lora_b = args[12], args[13]
        kv_k, kv_v, tok, pos = args[14], args[15], args[16], args[17]
        x = p["embed"][tok][None, :]
        x, kv_k, kv_v = M.run_layers_decode(p, x, kv_k, kv_v, pos, 0, k, CFG)
        hk = x[0]
        logits = M.draft_head_logits(p, lora_a, lora_b, x, CFG)[0]
        return logits, hk, kv_k, kv_v

    return fn, ports, outs


@artifact("draft_block")
def _draft_block():
    """Fused k_spec-step draft loop (PERF, EXPERIMENTS.md §Perf): greedy
    argmax between steps happens in-graph, collapsing k_spec PJRT calls
    (and their host round-trips) into one. The per-step variant
    (`draft_step`) is kept for parity tests and ablation."""
    d, V, r = CFG.d_model, CFG.vocab_size, CFG.lora_rank
    k, B = CFG.split_layer, SPEC.k_spec
    ports = _shallow_ports() + [
        Port("dp.final_norm", (d,), F32, "weight"),
        Port("draft_base", (V, d), F32, "weight"),
        Port("lora.A", (V, r), F32, "global"),
        Port("lora.B", (r, d), F32, "global"),
        Port("kv_sh_k", _kv_shape(k), F32, "kv"),
        Port("kv_sh_v", _kv_shape(k), F32, "kv"),
        Port("tok", (), I32, "in"),
        Port("pos", (), I32, "in"),
    ]
    outs = [
        Port("drafted", (B,), I32, "out"),
        Port("hk_rows", (B, d), F32, "out"),
        Port("kv_sh_k", _kv_shape(k), F32, "kv"),
        Port("kv_sh_v", _kv_shape(k), F32, "kv"),
    ]

    def fn(*args):
        p = _params_from(ports, args, "sh")
        p["final_norm"] = args[10]
        p["draft_base"] = args[11]
        lora_a, lora_b = args[12], args[13]
        kv_k, kv_v, tok, pos = args[14], args[15], args[16], args[17]
        drafted, hks = [], []
        for i in range(B):
            x = p["embed"][tok][None, :]
            x, kv_k, kv_v = M.run_layers_decode(p, x, kv_k, kv_v, pos + i,
                                                0, k, CFG)
            hks.append(x[0])
            logits = M.draft_head_logits(p, lora_a, lora_b, x, CFG)[0]
            tok = jnp.argmax(logits).astype(jnp.int32)
            drafted.append(tok)
        return jnp.stack(drafted), jnp.stack(hks), kv_k, kv_v

    return fn, ports, outs


@artifact("verify_block")
def _verify_block():
    d, V = CFG.d_model, CFG.vocab_size
    n, B = CFG.deep_layers, SPEC.k_spec
    ports = _deep_ports() + [
        Port("dp.final_norm", (d,), F32, "weight"),
        Port("dp.lm_head", (V, d), F32, "weight"),
        Port("kv_dp_k", _kv_shape(n), F32, "kv"),
        Port("kv_dp_v", _kv_shape(n), F32, "kv"),
        Port("hk_block", (B, d), F32, "in"),
        Port("pos", (), I32, "in"),
    ]
    outs = [
        Port("logits_phi", (B, V), F32, "out"),
        Port("kv_dp_k", _kv_shape(n), F32, "kv"),
        Port("kv_dp_v", _kv_shape(n), F32, "kv"),
    ]

    def fn(*args):
        p = _params_from(ports, args, "dp")
        kv_k, kv_v, hk, pos = args[11], args[12], args[13], args[14]
        # Deep path: layer indices split..L map to cache rows 0..n-1; the
        # params dict here holds only deep tensors so lo=0, hi=n.
        x, kv_k, kv_v = M.run_layers_decode(p, hk, kv_k, kv_v, pos, 0, n, CFG)
        logits = M.verifier_logits(p, x, CFG)
        return logits, kv_k, kv_v

    return fn, ports, outs


@artifact("prefill_shallow")
def _prefill_shallow():
    d, k, P = CFG.d_model, CFG.split_layer, SPEC.prefill_seq
    ports = _shallow_ports() + [
        Port("tokens", (P,), I32, "in"),
    ]
    outs = [
        Port("hk_seq", (P, d), F32, "out"),
        Port("kv_sh_k", _kv_shape(k), F32, "kv"),
        Port("kv_sh_v", _kv_shape(k), F32, "kv"),
    ]

    def fn(*args):
        p = _params_from(ports, args, "sh")
        tokens = args[10]
        x = p["embed"][tokens]
        x, kv_k, kv_v = M.run_layers_prefill(p, x, 0, k, CFG, CFG.max_seq)
        return x, kv_k, kv_v

    return fn, ports, outs


@artifact("prefill_deep")
def _prefill_deep():
    d, V = CFG.d_model, CFG.vocab_size
    n, P = CFG.deep_layers, SPEC.prefill_seq
    ports = _deep_ports() + [
        Port("dp.final_norm", (d,), F32, "weight"),
        Port("dp.lm_head", (V, d), F32, "weight"),
        Port("hk_seq", (P, d), F32, "in"),
        Port("length", (), I32, "in"),
    ]
    outs = [
        Port("logits_last", (V,), F32, "out"),
        Port("kv_dp_k", _kv_shape(n), F32, "kv"),
        Port("kv_dp_v", _kv_shape(n), F32, "kv"),
    ]

    def fn(*args):
        p = _params_from(ports, args, "dp")
        hk_seq, length = args[11], args[12]
        x, kv_k, kv_v = M.run_layers_prefill(p, hk_seq, 0, n, CFG, CFG.max_seq)
        last = jax.lax.dynamic_slice(x, (length - 1, 0), (1, x.shape[1]))
        logits = M.verifier_logits(p, last, CFG)[0]
        return logits, kv_k, kv_v

    return fn, ports, outs


def _full_ports(prefix, cfg):
    V, d = cfg.vocab_size, cfg.d_model
    return ([Port(f"{prefix}.embed", (V, d), F32, "weight")]
            + _deep_ports(prefix, cfg.n_layers, cfg)
            + [Port(f"{prefix}.final_norm", (d,), F32, "weight"),
               Port(f"{prefix}.lm_head", (V, d), F32, "weight")])


def _full_model_artifacts(prefix, cfg, kv_prefix):
    """prefill / step / verify-block for a *complete* model (backbone via
    prefix 'fl', SpS drafter via prefix 'sps')."""
    V, d, L = cfg.vocab_size, cfg.d_model, cfg.n_layers
    P, B = SPEC.prefill_seq, SPEC.k_spec
    kv = (L, CFG.max_seq, cfg.n_heads, cfg.head_dim)
    base = _full_ports(prefix, cfg)
    nb = len(base)

    def prefill():
        ports = base + [Port("tokens", (P,), I32, "in"),
                        Port("length", (), I32, "in")]
        outs = [Port("logits_last", (V,), F32, "out"),
                Port("hl_last", (d,), F32, "out"),
                Port(f"{kv_prefix}_k", kv, F32, "kv"),
                Port(f"{kv_prefix}_v", kv, F32, "kv")]

        def fn(*args):
            p = _params_from(ports, args, prefix)
            tokens, length = args[nb], args[nb + 1]
            x = p["embed"][tokens]
            x, kv_k, kv_v = M.run_layers_prefill(p, x, 0, L, cfg, CFG.max_seq)
            last = jax.lax.dynamic_slice(x, (length - 1, 0), (1, d))
            logits = M.verifier_logits(p, last, cfg)[0]
            return logits, last[0], kv_k, kv_v

        return fn, ports, outs

    def step():
        ports = base + [Port(f"{kv_prefix}_k", kv, F32, "kv"),
                        Port(f"{kv_prefix}_v", kv, F32, "kv"),
                        Port("tok", (), I32, "in"),
                        Port("pos", (), I32, "in")]
        outs = [Port("logits", (V,), F32, "out"),
                Port("hl", (d,), F32, "out"),
                Port(f"{kv_prefix}_k", kv, F32, "kv"),
                Port(f"{kv_prefix}_v", kv, F32, "kv")]

        def fn(*args):
            p = _params_from(ports, args, prefix)
            kv_k, kv_v, tok, pos = args[nb], args[nb + 1], args[nb + 2], args[nb + 3]
            x = p["embed"][tok][None, :]
            x, kv_k, kv_v = M.run_layers_decode(p, x, kv_k, kv_v, pos, 0, L, cfg)
            logits = M.verifier_logits(p, x, cfg)[0]
            return logits, x[0], kv_k, kv_v

        return fn, ports, outs

    def verify():
        ports = base + [Port(f"{kv_prefix}_k", kv, F32, "kv"),
                        Port(f"{kv_prefix}_v", kv, F32, "kv"),
                        Port("toks", (B,), I32, "in"),
                        Port("pos", (), I32, "in")]
        outs = [Port("logits", (B, V), F32, "out"),
                Port("hl_block", (B, d), F32, "out"),
                Port(f"{kv_prefix}_k", kv, F32, "kv"),
                Port(f"{kv_prefix}_v", kv, F32, "kv")]

        def fn(*args):
            p = _params_from(ports, args, prefix)
            kv_k, kv_v, toks, pos = args[nb], args[nb + 1], args[nb + 2], args[nb + 3]
            x = p["embed"][toks]
            x, kv_k, kv_v = M.run_layers_decode(p, x, kv_k, kv_v, pos, 0, L, cfg)
            logits = M.verifier_logits(p, x, cfg)
            return logits, x, kv_k, kv_v

        return fn, ports, outs

    return prefill, step, verify


(_pf, _st, _vf) = _full_model_artifacts("fl", CFG, "kv_fl")
ARTIFACTS["prefill_full"] = _pf
ARTIFACTS["target_step"] = _st
ARTIFACTS["target_verify_block"] = _vf

(_spf, _sst, _svf) = _full_model_artifacts("sps", SPS_CFG, "kv_sps")
ARTIFACTS["sps_prefill"] = _spf
ARTIFACTS["sps_draft_step"] = _sst


@artifact("train_step")
def _train_step():
    d, V, r = CFG.d_model, CFG.vocab_size, CFG.lora_rank
    N = TCFG.batch_size
    ports = [
        Port("draft_base", (V, d), F32, "weight"),
        Port("dp.final_norm", (d,), F32, "weight"),
        Port("lora.A", (V, r), F32, "global"),
        Port("lora.B", (r, d), F32, "global"),
        Port("adam.mA", (V, r), F32, "global"),
        Port("adam.vA", (V, r), F32, "global"),
        Port("adam.mB", (r, d), F32, "global"),
        Port("adam.vB", (r, d), F32, "global"),
        Port("hk", (N, d), F32, "in"),
        Port("actions", (N,), I32, "in"),
        Port("logits_phi", (N, V), F32, "in"),
        Port("rewards", (N,), F32, "in"),
        Port("mask", (N,), F32, "in"),
        Port("hyper", (T.HYPER_LEN,), F32, "in"),
    ]
    outs = [
        Port("metrics", (T.METRICS_LEN,), F32, "out"),
        Port("lora.A", (V, r), F32, "global"),
        Port("lora.B", (r, d), F32, "global"),
        Port("adam.mA", (V, r), F32, "global"),
        Port("adam.vA", (V, r), F32, "global"),
        Port("adam.mB", (r, d), F32, "global"),
        Port("adam.vB", (r, d), F32, "global"),
    ]

    def fn(draft_base, final_norm, a, b, m_a, v_a, m_b, v_b,
           hk, actions, logits_phi, rewards, mask, hyper):
        frozen = {"draft_base": draft_base, "final_norm": final_norm}
        a, b, m_a, v_a, m_b, v_b, metrics = T.train_step(
            frozen, a, b, m_a, v_a, m_b, v_b,
            hk, actions, logits_phi, rewards, mask, hyper, CFG, TCFG)
        return metrics, a, b, m_a, v_a, m_b, v_b

    return fn, ports, outs


@artifact("medusa_heads")
def _medusa_heads():
    d, V = CFG.d_model, CFG.vocab_size
    ports = [
        Port("med.U", (MEDUSA_HEADS, d, MEDUSA_HIDDEN), F32, "weight"),
        Port("med.W", (MEDUSA_HEADS, MEDUSA_HIDDEN, V), F32, "weight"),
        Port("dp.final_norm", (d,), F32, "weight"),
        Port("hl", (d,), F32, "in"),
    ]
    outs = [Port("logits", (MEDUSA_HEADS, V), F32, "out")]

    def fn(u, w, norm, hl):
        hln = M.rmsnorm(hl, norm, CFG.norm_eps)
        return (medusa_logits({"U": u, "W": w}, hln),)

    return fn, ports, outs


@artifact("hydra_chain")
def _hydra_chain():
    d, V = CFG.d_model, CFG.vocab_size
    K = MEDUSA_HEADS
    ports = [
        Port("hy.W0", (d, HYDRA_HIDDEN), F32, "weight"),
        Port("hy.Ws", (HYDRA_HIDDEN, HYDRA_HIDDEN), F32, "weight"),
        Port("hy.We", (d, HYDRA_HIDDEN), F32, "weight"),
        Port("hy.W", (HYDRA_HIDDEN, V), F32, "weight"),
        Port("fl.embed", (V, d), F32, "weight"),
        Port("dp.final_norm", (d,), F32, "weight"),
        Port("hl", (d,), F32, "in"),
        Port("tok0", (), I32, "in"),
    ]
    outs = [Port("toks", (K,), I32, "out"),
            Port("logits", (K, V), F32, "out")]

    def fn(w0, ws, we, w, embed, norm, hl, tok0):
        hln = M.rmsnorm(hl, norm, CFG.norm_eps)
        s = jax.nn.silu(hln @ w0)
        tok = tok0
        toks, logits = [], []
        for _ in range(K):
            s = jax.nn.silu(s @ ws + embed[tok] @ we)
            lg = s @ w
            tok = jnp.argmax(lg).astype(jnp.int32)
            toks.append(tok)
            logits.append(lg)
        return jnp.stack(toks), jnp.stack(logits)

    return fn, ports, outs


@artifact("eagle_step")
def _eagle_step():
    d, V = CFG.d_model, CFG.vocab_size
    ports = [
        Port("ea.W1", (2 * d, EAGLE_HIDDEN), F32, "weight"),
        Port("ea.W2", (EAGLE_HIDDEN, d), F32, "weight"),
        Port("fl.embed", (V, d), F32, "weight"),
        Port("dp.final_norm", (d,), F32, "weight"),
        Port("dp.lm_head", (V, d), F32, "weight"),
        Port("feat", (d,), F32, "in"),
        Port("tok", (), I32, "in"),
    ]
    outs = [Port("logits", (V,), F32, "out"),
            Port("feat_next", (d,), F32, "out")]

    def fn(w1, w2, embed, norm, head, feat, tok):
        f = eagle_predict({"W1": w1, "W2": w2}, feat, embed[tok])
        logits = M.rmsnorm(f, norm, CFG.norm_eps) @ head.T
        return logits, f

    return fn, ports, outs


# ----------------------------------------------------------------------------
# Packaging: weights.bin, prompts, vocab, manifest
# ----------------------------------------------------------------------------

DT_CODE = {"float32": 0, "int32": 1}


def write_weights_bin(path: str, tensors: dict):
    with open(path, "wb") as f:
        f.write(b"DVIW")
        f.write(struct.pack("<II", 1, len(tensors)))
        for name, arr in sorted(tensors.items()):
            # NB: np.ascontiguousarray would promote 0-d scalars to 1-d;
            # np.asarray(order="C") preserves rank.
            arr = np.asarray(arr, order="C")
            code = DT_CODE[str(arr.dtype)]
            nb = name.encode()
            f.write(struct.pack("<I", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BI", code, arr.ndim))
            f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
            f.write(arr.tobytes())


TASK_IDS = {name: i for i, name in enumerate(
    ["mt", "translation", "summarization", "qa", "math", "rag"])}


def write_prompts_bin(path: str, samples, max_new: int):
    with open(path, "wb") as f:
        f.write(b"DVIP")
        f.write(struct.pack("<II", 1, len(samples)))
        for s in samples:
            ids = np.asarray(s.prompt, dtype=np.uint32)
            ans = np.asarray(s.answer, dtype=np.uint32)
            f.write(struct.pack("<IIII", TASK_IDS[s.task], max_new,
                                len(ids), len(ans)))
            f.write(ids.tobytes())
            f.write(ans.tobytes())


def export(out_dir: str, backbone_path: str, heads_path: str | None,
           only: list | None = None):
    os.makedirs(out_dir, exist_ok=True)
    os.makedirs(os.path.join(out_dir, "prompts"), exist_ok=True)

    params = {k: jnp.asarray(v) for k, v in np.load(backbone_path).items()}
    tensors = split_weights(params)
    if heads_path and os.path.exists(heads_path):
        tensors.update({k: np.asarray(v) for k, v in np.load(heads_path).items()})

    # LoRA / Adam initial values (role=global buffers start from these).
    lora = M.init_lora(CFG, jax.random.PRNGKey(42))
    tensors["lora.A"] = lora["A"]
    tensors["lora.B"] = lora["B"]
    for n, shape in (("adam.mA", lora["A"].shape), ("adam.vA", lora["A"].shape),
                     ("adam.mB", lora["B"].shape), ("adam.vB", lora["B"].shape)):
        tensors[n] = np.zeros(shape, np.float32)

    manifest = {"version": 1, "config": config_dict(), "artifacts": {}}
    names = only or list(ARTIFACTS.keys())
    for name in names:
        build = ARTIFACTS[name]
        t0 = time.time()
        fn, ports, outs = build()
        missing = [p.name for p in ports
                   if p.role in ("weight", "global") and p.name not in tensors]
        if missing:
            print(f"  SKIP {name}: missing weights {missing}")
            continue
        donate = [i for i, p in enumerate(ports) if p.role == "kv"]
        hlo = to_hlo_text(fn, [_spec(p) for p in ports], donate)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(hlo)
        manifest["artifacts"][name] = {
            "file": fname,
            "params": [asdict(p) for p in ports],
            "outputs": [asdict(p) for p in outs],
        }
        print(f"  exported {name} ({time.time() - t0:.1f}s, "
              f"{len(hlo) // 1024}KB)", flush=True)

    write_weights_bin(os.path.join(out_dir, "weights.bin"), tensors)

    with open(os.path.join(out_dir, "vocab.json"), "w") as f:
        json.dump(corpus.VOCAB, f)

    # Eval prompt sets (held-out seeds) + the ShareGPT-analogue stream.
    prompt_index = {}
    for i, task in enumerate(TASK_IDS):
        samples = corpus.eval_prompts(task, 100, corpus.EVAL_SEED_BASE + i)
        fname = f"prompts/{task}.bin"
        write_prompts_bin(os.path.join(out_dir, fname), samples,
                          SPEC.max_new_tokens)
        prompt_index[task] = fname
    stream = corpus.sharegpt_stream(2000, corpus.STREAM_SEED)
    write_prompts_bin(os.path.join(out_dir, "prompts/stream.bin"), stream,
                      SPEC.max_new_tokens)
    prompt_index["stream"] = "prompts/stream.bin"
    manifest["prompts"] = prompt_index
    manifest["weights"] = "weights.bin"
    manifest["vocab"] = "vocab.json"

    if os.path.exists(os.path.join(out_dir, "exposures.json")):
        with open(os.path.join(out_dir, "exposures.json")) as f:
            manifest["exposures"] = json.load(f)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(manifest['artifacts'])} artifacts -> {out_dir}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--backbone", default="../artifacts/backbone.npz")
    ap.add_argument("--heads", default="../artifacts/heads.npz")
    ap.add_argument("--only", nargs="*", default=None,
                    help="subset of artifact names")
    args = ap.parse_args()
    export(args.out, args.backbone, args.heads, args.only)


if __name__ == "__main__":
    main()
