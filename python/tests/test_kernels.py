"""L1 Pallas kernels vs the pure-jnp oracle (ref.py).

hypothesis sweeps shapes and magnitudes; every kernel is checked for both
forward numerics and (where a custom VJP exists) gradients.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import decode_attention, S_TILE
from compile.kernels.lora_head import lora_head, V_TILE
from compile.kernels.losses import fused_losses, N_TILE

SETTINGS = dict(max_examples=12, deadline=None)


def _rng(seed):
    return np.random.default_rng(seed)


# ----------------------------------------------------------------------------
# lora_head
# ----------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    n=st.sampled_from([1, 2, 4, 8]),
    d=st.sampled_from([32, 64, 192]),
    v_tiles=st.integers(1, 4),
    r=st.sampled_from([4, 16, 32]),
    seed=st.integers(0, 2**31 - 1),
)
def test_lora_head_forward(n, d, v_tiles, r, seed):
    rng = _rng(seed)
    v = v_tiles * V_TILE
    h = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(v, d)) * 0.1, jnp.float32)
    a = jnp.asarray(rng.normal(size=(v, r)) * 0.1, jnp.float32)
    b = jnp.asarray(rng.normal(size=(r, d)) * 0.1, jnp.float32)
    got = lora_head(h, w, a, b, 2.0)
    want = ref.lora_head(h, w, a, b, 2.0)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


@settings(**SETTINGS)
@given(
    n=st.sampled_from([1, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_lora_head_grads(n, seed):
    rng = _rng(seed)
    d, v, r = 64, V_TILE * 2, 8
    h = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(v, d)) * 0.1, jnp.float32)
    a = jnp.asarray(rng.normal(size=(v, r)) * 0.1, jnp.float32)
    b = jnp.asarray(rng.normal(size=(r, d)) * 0.1, jnp.float32)
    g = jnp.asarray(rng.normal(size=(n, v)), jnp.float32)

    def loss_k(a_, b_, h_):
        return (lora_head(h_, w, a_, b_, 2.0) * g).sum()

    def loss_r(a_, b_, h_):
        return (ref.lora_head(h_, w, a_, b_, 2.0) * g).sum()

    gk = jax.grad(loss_k, (0, 1, 2))(a, b, h)
    gr = jax.grad(loss_r, (0, 1, 2))(a, b, h)
    for x, y, name in zip(gk, gr, ["dA", "dB", "dh"]):
        np.testing.assert_allclose(x, y, atol=3e-5, rtol=3e-5, err_msg=name)


def test_lora_head_zero_adapter_is_base_head():
    rng = _rng(0)
    n, d, v, r = 4, 192, 512, 32
    h = jnp.asarray(rng.normal(size=(n, d)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(v, d)) * 0.1, jnp.float32)
    a = jnp.zeros((v, r), jnp.float32)  # LoRA cold-start init
    b = jnp.asarray(rng.normal(size=(r, d)) * 0.1, jnp.float32)
    got = lora_head(h, w, a, b, 2.0)
    np.testing.assert_allclose(got, h @ w.T, atol=1e-5)


def test_lora_head_rejects_unaligned_vocab():
    h = jnp.zeros((2, 16), jnp.float32)
    w = jnp.zeros((100, 16), jnp.float32)  # not a multiple of V_TILE
    a = jnp.zeros((100, 4), jnp.float32)
    b = jnp.zeros((4, 16), jnp.float32)
    with pytest.raises(AssertionError):
        lora_head(h, w, a, b, 1.0)


# ----------------------------------------------------------------------------
# decode_attention
# ----------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    bq=st.sampled_from([1, 2, 4]),
    heads=st.sampled_from([1, 2, 6]),
    hd=st.sampled_from([8, 32]),
    s_tiles=st.integers(1, 5),
    seed=st.integers(0, 2**31 - 1),
)
def test_attention_matches_ref(bq, heads, hd, s_tiles, seed):
    rng = _rng(seed)
    s = s_tiles * S_TILE
    q = jnp.asarray(rng.normal(size=(bq, heads, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(s, heads, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(s, heads, hd)), jnp.float32)
    pos = int(rng.integers(0, s - bq))
    got = decode_attention(q, k, v, pos)
    want = ref.decode_attention(q, k, v, pos)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_attention_masks_stale_slots():
    """Garbage written beyond the mask must not affect the output — the
    rollback-correctness property the Rust coordinator relies on."""
    rng = _rng(7)
    bq, h, hd, s = 2, 2, 16, S_TILE * 2
    q = jnp.asarray(rng.normal(size=(bq, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(s, h, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(s, h, hd)), jnp.float32)
    pos = 10
    out1 = decode_attention(q, k, v, pos)
    # poison all slots beyond pos+bq-1
    k2 = k.at[pos + bq:].set(1e3)
    v2 = v.at[pos + bq:].set(-1e3)
    out2 = decode_attention(q, k2, v2, pos)
    np.testing.assert_allclose(out1, out2, atol=1e-6)


def test_attention_causal_within_block():
    """Query i must not see key i+1 of the same block."""
    rng = _rng(8)
    h, hd, s = 1, 8, S_TILE
    k = jnp.asarray(rng.normal(size=(s, h, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(s, h, hd)), jnp.float32)
    q = jnp.asarray(rng.normal(size=(2, h, hd)), jnp.float32)
    pos = 5
    out_block = decode_attention(q, k, v, pos)
    # query 0 alone must equal its value in the block
    out_single = decode_attention(q[:1], k, v, pos)
    np.testing.assert_allclose(out_block[0], out_single[0], atol=1e-5)


def test_attention_pos_zero():
    rng = _rng(9)
    q = jnp.asarray(rng.normal(size=(1, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(S_TILE, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(S_TILE, 2, 8)), jnp.float32)
    got = decode_attention(q, k, v, 0)
    # only slot 0 visible -> output = v[0]
    np.testing.assert_allclose(got[0], v[0], atol=1e-5)


# ----------------------------------------------------------------------------
# fused_losses
# ----------------------------------------------------------------------------

@settings(**SETTINGS)
@given(
    rows=st.integers(1, 8),
    v=st.sampled_from([32, 512]),
    tau=st.sampled_from([0.5, 1.0, 2.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_losses_match_ref(rows, v, tau, seed):
    rng = _rng(seed)
    n = rows * N_TILE
    zt = jnp.asarray(rng.normal(size=(n, v)) * 2, jnp.float32)
    zp = jnp.asarray(rng.normal(size=(n, v)) * 2, jnp.float32)
    a = jnp.asarray(rng.integers(0, v, size=(n,)), jnp.int32)
    got = fused_losses(zt, zp, a, tau)
    want = ref.fused_losses(zt, zp, a, tau)
    for g, w, name in zip(got, want, ["ce", "kl", "ent", "logp"]):
        np.testing.assert_allclose(g, w, atol=3e-5, rtol=3e-5, err_msg=name)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1))
def test_losses_grads_match_ref(seed):
    rng = _rng(seed)
    n, v, tau = N_TILE * 2, 64, 1.3
    zt = jnp.asarray(rng.normal(size=(n, v)), jnp.float32)
    zp = jnp.asarray(rng.normal(size=(n, v)), jnp.float32)
    a = jnp.asarray(rng.integers(0, v, size=(n,)), jnp.int32)
    cw = jnp.asarray(rng.normal(size=(4, n)), jnp.float32)

    def lk(zt_, zp_):
        ce, kl, ent, lp = fused_losses(zt_, zp_, a, tau)
        return (cw[0] * ce + cw[1] * kl + cw[2] * ent + cw[3] * lp).sum()

    def lr(zt_, zp_):
        ce, kl, ent, lp = ref.fused_losses(zt_, zp_, a, tau)
        return (cw[0] * ce + cw[1] * kl + cw[2] * ent + cw[3] * lp).sum()

    gk = jax.grad(lk, (0, 1))(zt, zp)
    gr = jax.grad(lr, (0, 1))(zt, zp)
    np.testing.assert_allclose(gk[0], gr[0], atol=3e-5, rtol=3e-5)
    np.testing.assert_allclose(gk[1], gr[1], atol=3e-5, rtol=3e-5)


def test_losses_kl_properties():
    """KL >= 0; KL(p||p) == 0 at tau=1."""
    rng = _rng(11)
    n, v = N_TILE, 32
    z = jnp.asarray(rng.normal(size=(n, v)), jnp.float32)
    a = jnp.zeros((n,), jnp.int32)
    _, kl_same, _, _ = fused_losses(z, z, a, 1.0)
    np.testing.assert_allclose(kl_same, np.zeros(n), atol=1e-5)
    z2 = jnp.asarray(rng.normal(size=(n, v)), jnp.float32)
    _, kl, _, _ = fused_losses(z, z2, a, 1.0)
    assert (np.asarray(kl) >= -1e-6).all()


def test_losses_ce_is_neg_logp():
    rng = _rng(12)
    n, v = N_TILE, 48
    zt = jnp.asarray(rng.normal(size=(n, v)), jnp.float32)
    zp = jnp.asarray(rng.normal(size=(n, v)), jnp.float32)
    a = jnp.asarray(rng.integers(0, v, size=(n,)), jnp.int32)
    ce, _, _, logp = fused_losses(zt, zp, a, 1.0)
    np.testing.assert_allclose(ce, -logp, atol=1e-6)
