"""L2 model invariants: decode-path == train-path numerics, KV masking,
split consistency, and the DVI loss/`train_step` against the oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import train as T
from compile.config import ModelConfig, TrainConfig
from compile.kernels import ref

CFG = ModelConfig(d_model=64, n_layers=4, n_heads=2, d_ff=128,
                  vocab_size=512, max_seq=64, split_layer=2, lora_rank=8)


@pytest.fixture(scope="module")
def params():
    p = M.init_params(CFG, jax.random.PRNGKey(0))
    p["draft_base"] = p["lm_head"]
    return p


@pytest.fixture(scope="module")
def toks():
    return jax.random.randint(jax.random.PRNGKey(1), (1, 16), 6, CFG.vocab_size)


def test_prefill_matches_train_forward(params, toks):
    logits_train = M.forward_train(params, toks, CFG)[0]
    x = params["embed"][toks[0]]
    hk, _, _ = M.run_layers_prefill(params, x, 0, CFG.split_layer, CFG, 64)
    hl, _, _ = M.run_layers_prefill(params, hk, CFG.split_layer, CFG.n_layers,
                                    CFG, 64)
    got = M.verifier_logits(params, hl, CFG)
    np.testing.assert_allclose(got, logits_train, atol=5e-5, rtol=1e-4)


def test_decode_steps_match_train_forward(params, toks):
    logits_train = M.forward_train(params, toks, CFG)[0]
    x = params["embed"][toks[0, :8]]
    hk, ks, vs = M.run_layers_prefill(params, x, 0, CFG.split_layer, CFG, 64)
    _, kd, vd = M.run_layers_prefill(params, hk, CFG.split_layer,
                                     CFG.n_layers, CFG, 64)
    for pos in range(8, 16):
        x1 = params["embed"][toks[0, pos]][None]
        x1, ks, vs = M.run_layers_decode(params, x1, ks, vs, pos, 0,
                                         CFG.split_layer, CFG)
        x1, kd, vd = M.run_layers_decode(params, x1, kd, vd, pos,
                                         CFG.split_layer, CFG.n_layers, CFG)
        got = M.verifier_logits(params, x1, CFG)[0]
        np.testing.assert_allclose(got, logits_train[pos], atol=5e-5, rtol=1e-4)


def test_verify_block_matches_train_forward(params, toks):
    """The self-speculative deep block over true h_k rows reproduces the
    full model exactly — the losslessness precondition."""
    logits_train = M.forward_train(params, toks, CFG)[0]
    x = params["embed"][toks[0]]
    hk_all, _, _ = M.run_layers_prefill(params, x, 0, CFG.split_layer, CFG, 64)
    _, kd, vd = M.run_layers_prefill(params, hk_all[:8], CFG.split_layer,
                                     CFG.n_layers, CFG, 64)
    blk, kd, vd = M.run_layers_decode(params, hk_all[8:12], kd, vd, 8,
                                      CFG.split_layer, CFG.n_layers, CFG)
    got = M.verifier_logits(params, blk, CFG)
    np.testing.assert_allclose(got, logits_train[8:12], atol=5e-5, rtol=1e-4)


def test_stale_kv_slots_do_not_leak(params, toks):
    """Writing speculative garbage beyond the feed position then re-feeding
    at the same position must give identical logits (rollback safety)."""
    x = params["embed"][toks[0, :8]]
    hk, ks, vs = M.run_layers_prefill(params, x, 0, CFG.split_layer, CFG, 64)

    x_cln = params["embed"][toks[0, 8]][None]
    clean, ks2, _ = M.run_layers_decode(params, x_cln, ks, vs, 8, 0,
                                        CFG.split_layer, CFG)
    # poison: run three bogus speculative steps at 8,9,10 first
    ks_p, vs_p = ks, vs
    for pos in range(8, 11):
        bogus = params["embed"][5][None]
        _, ks_p, vs_p = M.run_layers_decode(params, bogus, ks_p, vs_p, pos, 0,
                                            CFG.split_layer, CFG)
    redo, _, _ = M.run_layers_decode(params, x_cln, ks_p, vs_p, 8, 0,
                                     CFG.split_layer, CFG)
    np.testing.assert_allclose(clean, redo, atol=1e-5)


def test_lora_init_zero_matches_base_head(params):
    lora = M.init_lora(CFG, jax.random.PRNGKey(3))
    hk = jax.random.normal(jax.random.PRNGKey(4), (4, CFG.d_model))
    got = M.draft_head_logits(params, lora["A"], lora["B"], hk, CFG)
    hkn = M.rmsnorm(hk, params["final_norm"], CFG.norm_eps)
    np.testing.assert_allclose(got, hkn @ params["lm_head"].T, atol=1e-5)


def test_rope_position_dependence():
    x = jnp.ones((3, 2, 16))
    r0 = M.rope(x, jnp.array([0, 1, 2]), 10000.0)
    r1 = M.rope(x, jnp.array([1, 2, 3]), 10000.0)
    # position 1 computed under either offset must agree
    np.testing.assert_allclose(r0[1], r1[0], atol=1e-6)
    assert not np.allclose(r0[0], r0[2])


def test_rope_zero_position_identity():
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 2, 16))
    r = M.rope(x, jnp.array([0]), 10000.0)
    np.testing.assert_allclose(r, x, atol=1e-6)


# ----------------------------------------------------------------------------
# DVI loss + train step
# ----------------------------------------------------------------------------

TCFG = TrainConfig(batch_size=16)


def _batch(n=16, v=512, d=64, seed=0):
    rng = np.random.default_rng(seed)
    return dict(
        hk=jnp.asarray(rng.normal(size=(n, d)), jnp.float32),
        actions=jnp.asarray(rng.integers(0, v, size=(n,)), jnp.int32),
        logits_phi=jnp.asarray(rng.normal(size=(n, v)) * 2, jnp.float32),
        rewards=jnp.asarray(rng.integers(0, 2, size=(n,)), jnp.float32),
        mask=jnp.ones((n,), jnp.float32),
    )


def test_dvi_loss_matches_oracle(params):
    b = _batch()
    lora = M.init_lora(CFG, jax.random.PRNGKey(6))
    a = lora["A"] + 0.01
    logits_theta = M.draft_head_logits(params, a, lora["B"], b["hk"], CFG)
    hyper = jnp.asarray([0.5, 1.0, 0.5, 0.01, 0.5, 0.6, 1e-3, 1.0])
    total, parts = T.dvi_loss(logits_theta, b["logits_phi"], b["actions"],
                              b["rewards"], b["mask"], hyper, 1.0)
    want, want_parts = ref.dvi_loss(
        logits_theta, b["logits_phi"], b["actions"], b["rewards"], b["mask"],
        lam_pg=0.5, lam_kl=1.0, w_ce=0.5, w_ent=0.01, tau=1.0, w_rl=0.5,
        baseline=0.6)
    np.testing.assert_allclose(total, want, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(parts, want_parts, atol=1e-5, rtol=1e-5)


def test_train_step_reduces_kl(params):
    """A few KL-only steps must reduce KL(p_theta || p_phi) on a fixed
    batch — the optimizer actually descends."""
    b = _batch(seed=1)
    lora = M.init_lora(CFG, jax.random.PRNGKey(7))
    frozen = {"draft_base": params["draft_base"],
              "final_norm": params["final_norm"]}
    a, bb = lora["A"], lora["B"]
    ma, va = jnp.zeros_like(a), jnp.zeros_like(a)
    mb, vb = jnp.zeros_like(bb), jnp.zeros_like(bb)

    def kl_now(a, bb):
        lt = M.draft_head_logits(frozen, a, bb, b["hk"], CFG)
        _, kl, _, _ = ref.fused_losses(lt, b["logits_phi"], b["actions"], 1.0)
        return float(kl.mean())

    kl0 = kl_now(a, bb)
    step = jax.jit(lambda *xs: T.train_step(frozen, *xs, mcfg=CFG, tcfg=TCFG))
    for t in range(1, 11):
        hyper = jnp.asarray([0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 5e-3, float(t)])
        a, bb, ma, va, mb, vb, metrics = step(
            a, bb, ma, va, mb, vb, b["hk"], b["actions"], b["logits_phi"],
            b["rewards"], b["mask"], hyper)
    kl1 = kl_now(a, bb)
    assert kl1 < kl0 * 0.9, f"KL did not descend: {kl0} -> {kl1}"
    m = np.asarray(metrics)
    assert np.isfinite(m).all()


def test_train_step_zero_lr_is_identity(params):
    b = _batch(seed=2)
    lora = M.init_lora(CFG, jax.random.PRNGKey(8))
    frozen = {"draft_base": params["draft_base"],
              "final_norm": params["final_norm"]}
    a, bb = lora["A"] + 0.05, lora["B"]
    z = jnp.zeros_like
    hyper = jnp.asarray([0.5, 1.0, 0.5, 0.01, 0.5, 0.0, 0.0, 1.0])  # lr=0
    a2, b2, *_rest, metrics = T.train_step(
        frozen, a, bb, z(a), z(a), z(bb), z(bb),
        b["hk"], b["actions"], b["logits_phi"], b["rewards"], b["mask"],
        hyper, CFG, TCFG)
    np.testing.assert_allclose(a2, a, atol=1e-7)
    np.testing.assert_allclose(b2, bb, atol=1e-7)


def test_train_step_batch_accept_metric(params):
    b = _batch(seed=3)
    lora = M.init_lora(CFG, jax.random.PRNGKey(9))
    frozen = {"draft_base": params["draft_base"],
              "final_norm": params["final_norm"]}
    z = jnp.zeros_like
    hyper = jnp.asarray([0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 1e-3, 1.0])
    *_out, metrics = T.train_step(
        frozen, lora["A"], lora["B"], z(lora["A"]), z(lora["A"]),
        z(lora["B"]), z(lora["B"]),
        b["hk"], b["actions"], b["logits_phi"], b["rewards"], b["mask"],
        hyper, CFG, TCFG)
    expect = float(b["rewards"].mean())
    assert abs(float(metrics[6]) - expect) < 1e-6
