"""Corpus/vocab invariants: determinism, vocab closure, task structure."""

import random

import pytest
from hypothesis import given, settings, strategies as st

from compile import corpus as C


def test_vocab_is_exactly_512_unique():
    assert len(C.VOCAB) == 512
    assert len(set(C.VOCAB)) == 512


def test_specials_fixed_ids():
    assert C.VOCAB[C.PAD] == "<pad>"
    assert C.VOCAB[C.BOS] == "<bos>"
    assert C.VOCAB[C.EOS] == "<eos>"
    assert C.VOCAB[C.SEP] == "<sep>"


def test_encode_decode_roundtrip():
    words = ["translate", ":", "ent01", "<sep>"]
    assert C.decode(C.encode(words)) == words


@settings(max_examples=20, deadline=None)
@given(task=st.sampled_from(sorted(C.TASKS)), seed=st.integers(0, 10_000))
def test_samples_well_formed(task, seed):
    s = C.make_sample(task, random.Random(seed))
    assert s.prompt[0] == C.BOS
    assert s.prompt[-1] == C.TOK["<sep>"]
    assert s.answer[-1] == C.EOS
    assert all(0 <= t < 512 for t in s.prompt + s.answer)
    # prompt must fit the prefill artifact
    assert len(s.prompt) <= 160, f"{task} prompt too long: {len(s.prompt)}"
    assert 1 <= len(s.answer) <= 64


@settings(max_examples=10, deadline=None)
@given(task=st.sampled_from(sorted(C.TASKS)), seed=st.integers(0, 10_000))
def test_generators_deterministic(task, seed):
    a = C.make_sample(task, random.Random(seed))
    b = C.make_sample(task, random.Random(seed))
    assert a.prompt == b.prompt and a.answer == b.answer


def test_translation_is_deterministic_mapping():
    s = C.make_sample("translation", random.Random(3))
    words = C.decode(s.prompt)
    src = words[3:-1]  # skip BOS translate :, drop <sep>
    tgt = C.decode(s.answer)[:-1]
    assert [C.TRANSLATION[w] for w in src] == tgt


def test_math_answers_correct():
    for seed in range(30):
        s = C.make_sample("math", random.Random(seed))
        words = C.decode(s.prompt)
        expr = "".join(words[3:-2])  # digits and op between ':' and '='
        expect = eval(expr)  # noqa: S307 - synthetic digits/ops only
        got = "".join(C.decode(s.answer)[:-1])
        assert int(got.replace("-", "-")) == expect, (expr, got)


def test_qa_answers_match_kb():
    for seed in range(30):
        s = C.make_sample("qa", random.Random(seed))
        words = C.decode(s.prompt)
        rel, ent = words[4], words[5]
        assert C.decode(s.answer)[0] == C.KB[(ent, rel)]


def test_rag_context_contains_answer_fact():
    for seed in range(30):
        s = C.make_sample("rag", random.Random(seed))
        words = C.decode(s.prompt)
        ans = C.decode(s.answer)
        fact = " ".join(ans[:4])
        assert fact in " ".join(words), f"fact '{fact}' not in context"


def test_stream_mix_differs_from_eval_mix():
    stream = C.sharegpt_stream(500, C.STREAM_SEED)
    counts = {}
    for s in stream:
        counts[s.task] = counts.get(s.task, 0) + 1
    # assistant-flavoured: mt should dominate, math should be rare
    assert counts.get("mt", 0) > counts.get("math", 0)


def test_eval_seeds_disjoint_from_stream():
    # Hold-out property on a task with a large prompt space (translation:
    # 100^4..100^10 possible prompts). Small discrete tasks like QA
    # (48 entities x 8 relations) overlap unavoidably — see DESIGN.md.
    ev = {tuple(s.prompt)
          for s in C.eval_prompts("translation", 100, C.EVAL_SEED_BASE + 1)}
    st_ = {tuple(s.prompt) for s in C.sharegpt_stream(2000, C.STREAM_SEED)
           if s.task == "translation"}
    assert len(ev & st_) == 0


def test_token_stream_packing():
    toks = C.token_stream(1, 5_000)
    assert len(toks) == 5_000
    assert all(0 <= t < 512 for t in toks)
    assert toks.count(C.BOS) > 10  # multiple documents packed
