//! End-to-end serving driver (the repository's E2E validation run —
//! EXPERIMENTS.md §E2E): start the router with a worker pool and the
//! online learner, replay a mixed live-traffic stream through it, and
//! report latency percentiles, throughput, acceptance drift, and learner
//! progress.
//!
//!   cargo run --release --example serve_workload -- artifacts 300

use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use dvi::harness::load_prompts;
use dvi::learner::Objective;
use dvi::runtime::Runtime;
use dvi::server::{Router, RouterConfig};

fn pct(sorted_ms: &[f64], p: f64) -> f64 {
    if sorted_ms.is_empty() {
        return 0.0;
    }
    let idx = ((sorted_ms.len() - 1) as f64 * p).round() as usize;
    sorted_ms[idx]
}

fn main() -> Result<()> {
    let mut args = std::env::args().skip(1);
    let dir = args.next().unwrap_or_else(|| "artifacts".into());
    let n: usize = args.next().and_then(|s| s.parse().ok()).unwrap_or(300);

    let rt = Arc::new(Runtime::load_auto(dir.as_ref())?);
    let stream = load_prompts(&rt, "stream")?;
    let router = Router::start(
        rt,
        RouterConfig {
            workers: 2,
            method: "dvi".into(),
            online: true,
            objective: Objective::Dvi,
            buffer_capacity: 8192,
            ..RouterConfig::default()
        },
    )?;

    println!("== serving {n} live-traffic prompts through the DVI router ==");
    let mut latencies_ms: Vec<f64> = Vec::with_capacity(n);
    let mut accepts: Vec<f64> = Vec::with_capacity(n);
    let mut tokens = 0usize;
    let t0 = Instant::now();
    for (i, s) in stream.samples.iter().take(n).enumerate() {
        let t = Instant::now();
        let resp = router.generate(s.prompt.clone(), s.max_new)?;
        latencies_ms.push(t.elapsed().as_secs_f64() * 1e3);
        accepts.push(resp.acceptance);
        tokens += resp.tokens.len();
        if (i + 1) % 50 == 0 {
            let recent: f64 =
                accepts[accepts.len() - 50..].iter().sum::<f64>() / 50.0;
            println!(
                "  {:4}/{n}  acceptance(last50) = {recent:.3}  \
                 train_steps = {}",
                i + 1,
                router
                    .stats
                    .train_steps
                    .load(std::sync::atomic::Ordering::Relaxed)
            );
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    let mut sorted = latencies_ms.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let first50: f64 = accepts[..50.min(accepts.len())].iter().sum::<f64>()
        / 50.min(accepts.len()) as f64;
    let last50: f64 = accepts[accepts.len().saturating_sub(50)..]
        .iter()
        .sum::<f64>()
        / 50.min(accepts.len()) as f64;

    println!("\n== report ==");
    println!("prompts        : {n}");
    println!("wall time      : {wall:.1}s");
    println!("tokens         : {tokens} ({:.1} tok/s end-to-end)",
             tokens as f64 / wall);
    println!("latency p50    : {:.1} ms", pct(&sorted, 0.50));
    println!("latency p90    : {:.1} ms", pct(&sorted, 0.90));
    println!("latency p99    : {:.1} ms", pct(&sorted, 0.99));
    println!("acceptance     : first50 {first50:.3} -> last50 {last50:.3}");
    println!(
        "train steps    : {}",
        router.stats.train_steps.load(std::sync::atomic::Ordering::Relaxed)
    );
    router.shutdown();
    Ok(())
}
