//! Distribution-shift demo — the paper's core motivation (§1): offline
//! drafters go brittle when traffic drifts; DVI adapts online.
//!
//! Phase A: online-train the drafter on QA-style traffic and watch
//!          acceptance climb.
//! Phase B: switch traffic to translation (a different distribution) —
//!          acceptance drops, then RECOVERS as verifier feedback keeps
//!          flowing, with no offline retraining.
//!
//!   cargo run --release --example online_adaptation -- artifacts

use std::sync::{Arc, Mutex};

use anyhow::Result;

use dvi::engine::dvi::DviEngine;
use dvi::engine::Engine;
use dvi::harness::load_prompts;
use dvi::learner::{Objective, ReplayBuffer, Schedule, Trainer};
use dvi::runtime::Runtime;
use dvi::util::plot::ascii_plot;

fn main() -> Result<()> {
    let dir = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "artifacts".to_string());
    let rt = Arc::new(Runtime::load_auto(dir.as_ref())?);

    let buffer = Arc::new(Mutex::new(ReplayBuffer::new(8192)));
    let mut trainer = Trainer::new(
        rt.clone(), buffer.clone(), Schedule::new(Objective::Dvi), 7)?;
    trainer.reset()?;
    let mut engine = DviEngine::new(rt.clone())?.with_buffer(buffer);

    let qa = load_prompts(&rt, "qa")?;
    let translation = load_prompts(&rt, "translation")?;
    let phase_a = 150.min(qa.len());
    let phase_b = 150.min(translation.len());

    let mut curve: Vec<(f64, f64)> = Vec::new();
    let mut x = 0.0;

    println!("== phase A: QA traffic ({phase_a} prompts, online updates) ==");
    for s in qa.samples.iter().cycle().take(phase_a) {
        let r = engine.generate(&s.prompt, s.max_new)?;
        curve.push((x, r.acceptance_rate()));
        x += 1.0;
        trainer.maybe_train()?;
    }
    let a_end: f64 = curve[curve.len().saturating_sub(25)..]
        .iter().map(|(_, a)| a).sum::<f64>() / 25.0;

    println!("== phase B: traffic shifts to TRANSLATION ({phase_b} prompts) ==");
    let shift_x = x;
    for s in translation.samples.iter().cycle().take(phase_b) {
        let r = engine.generate(&s.prompt, s.max_new)?;
        curve.push((x, r.acceptance_rate()));
        x += 1.0;
        trainer.maybe_train()?;
    }

    // windowed means around the shift
    let win = |lo: f64, hi: f64| -> f64 {
        let v: Vec<f64> = curve.iter()
            .filter(|(cx, _)| *cx >= lo && *cx < hi)
            .map(|(_, a)| *a)
            .collect();
        v.iter().sum::<f64>() / v.len().max(1) as f64
    };
    let drop = win(shift_x, shift_x + 25.0);
    let recovered = win(x - 25.0, x);

    println!("{}", ascii_plot(
        "acceptance rate (traffic shifts QA -> translation at the midpoint)",
        &[("accept", &curve)], 72, 14));
    println!("phase A final acceptance : {a_end:.3}");
    println!("post-shift acceptance    : {drop:.3}   (drift penalty)");
    println!("after online adaptation  : {recovered:.3}");
    println!("learner steps            : {}", trainer.steps_done);
    Ok(())
}
