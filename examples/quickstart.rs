//! Quickstart: load the artifacts, run DVI self-speculative decoding on a
//! few prompts, and compare against the AR baseline.
//!
//!   make artifacts && cargo run --release --example quickstart

use std::sync::Arc;

use anyhow::Result;

use dvi::engine::Engine;
use dvi::harness::{load_prompts, make_engine};
use dvi::runtime::Runtime;

fn main() -> Result<()> {
    let dir = std::env::args()
        .nth(1)
        .unwrap_or_else(|| "artifacts".to_string());
    let rt = Arc::new(Runtime::load_auto(dir.as_ref())?);
    let tok = rt.tokenizer()?;

    let set = load_prompts(&rt, "qa")?;
    let mut ar = make_engine(rt.clone(), "ar")?;
    let mut dvi = make_engine(rt.clone(), "dvi")?;

    println!("== DVI quickstart: greedy QA decoding, AR vs self-speculative ==\n");
    for s in set.samples.iter().take(5) {
        let a = ar.generate(&s.prompt, s.max_new)?;
        let d = dvi.generate(&s.prompt, s.max_new)?;
        assert_eq!(a.tokens, d.tokens, "speculation must be lossless");
        println!("prompt : {}", tok.decode(&s.prompt[1..]));
        println!("output : {}", tok.decode(&d.tokens));
        println!(
            "         AR {:.1}ms | DVI {:.1}ms ({:.2}x) | MAT {:.2} | accept {:.0}%\n",
            a.decode_ns as f64 / 1e6,
            d.decode_ns as f64 / 1e6,
            a.decode_ns as f64 / d.decode_ns.max(1) as f64,
            d.mat(),
            d.acceptance_rate() * 100.0
        );
    }
    println!("(drafter is untrained here — run the online_adaptation example");
    println!(" or `dvi train` to watch acceptance climb)");
    Ok(())
}
