//! Table 2 regenerator: the Spec-Bench grid (7 methods x 6 tasks, MAT +
//! wall-time speedup + average). This is the paper's headline table.
//!
//!   cargo bench --bench table2_specbench
//!
//! Knobs: DVI_BENCH_N (prompts/task, default 25),
//!        DVI_BENCH_TRAIN (online prompts for DVI first, default 400),
//!        DVI_BENCH_METHODS (comma list).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use dvi::harness;
use dvi::learner::Objective;
use dvi::runtime::Runtime;

fn artifacts_dir() -> PathBuf {
    std::env::var("DVI_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

fn main() {
    let dir = artifacts_dir();
    let n: usize = std::env::var("DVI_BENCH_N")
        .ok().and_then(|s| s.parse().ok()).unwrap_or(6);
    let train: usize = std::env::var("DVI_BENCH_TRAIN")
        .ok().and_then(|s| s.parse().ok()).unwrap_or(200);
    let methods_env = std::env::var("DVI_BENCH_METHODS")
        .unwrap_or_else(|_| harness::METHODS.join(","));
    let methods: Vec<&str> = methods_env.split(',').collect();

    let rt = Arc::new(Runtime::load_auto(&dir).unwrap());
    if train > 0 && methods.contains(&"dvi") {
        eprintln!("[table2] online-training DVI on {train} prompts");
        harness::online_train(rt.clone(), Objective::Dvi, train, true).unwrap();
    }
    let result = harness::table2(rt, &methods, n).unwrap();
    println!("\n== Table 2 (Spec-Bench comparison; n={n}/task) ==\n");
    println!("{}", result.markdown);
    if let Ok(path) = std::env::var("DVI_BENCH_CSV") {
        std::fs::write(&path, &result.csv).unwrap();
        eprintln!("[table2] csv -> {path}");
    }
}
