//! Remote-executor overhead: `call_batched` on the local reference
//! backend vs the same backend behind the loopback remote transport
//! (full framing + binary codec + server dispatch + buffer table, no
//! sockets) — the per-call cost a deployment pays to move batched
//! execution out of process, before network latency.
//!
//! Second section: **serial vs pipelined** on one connection — the same
//! call set (independent KV groups, one batched call per group per
//! round) driven strict request/response (mux window 1, wait every
//! call) vs submitted back-to-back through `call_batched_submit` on a
//! protocol-v3 pipelined connection (window > 1), where encode/decode
//! of call N overlaps the executor running call N±1. Both drivers'
//! outputs are checked bitwise-identical before any timing is trusted.
//!
//!   cargo bench --bench remote_overhead
//!
//! Knobs: DVI_BENCH_LANES  lanes per batched call    (default 8)
//!        DVI_BENCH_ITERS  batched calls per artifact (default 200)
//!        DVI_BENCH_GROUPS independent chunk groups  (default 6)
//!        DVI_BENCH_TINY=1 CI smoke scale (20 iters)

use std::sync::Arc;
use std::time::Instant;

use dvi::runtime::{BatchHandle as _, BatchItem, Buffer, Runtime, Tensor};

const SEED: u64 = 0xBE7C4;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

struct Run {
    calls: usize,
    lanes: usize,
    wall_s: f64,
}

impl Run {
    fn us_per_call(&self) -> f64 {
        self.wall_s * 1e6 / self.calls as f64
    }

    fn us_per_lane_step(&self) -> f64 {
        self.us_per_call() / self.lanes as f64
    }
}

/// Drive `iters` batched decode-step calls with `lanes` independent
/// KV-chained sequences through one artifact. Positions cycle inside
/// the KV window; overwritten cache rows keep the computation
/// deterministic, which is all an overhead measurement needs.
fn drive(rt: &Runtime, artifact: &str, lanes: usize, iters: usize) -> Run {
    let art = rt.artifact(artifact).expect("artifact");
    let max_seq = rt.manifest.model_usize("max_seq").expect("max_seq");
    let k_spec = rt.manifest.spec_usize("k_spec").expect("k_spec");
    let mut kvs: Vec<Vec<Buffer>> = (0..lanes)
        .map(|_| rt.fresh_kv(artifact).expect("fresh kv"))
        .collect();
    let t0 = Instant::now();
    for i in 0..iters {
        let pos = (i % (max_seq.saturating_sub(k_spec + 1))) as i32;
        let inputs: Vec<Vec<Tensor>> = (0..lanes)
            .map(|l| {
                vec![
                    Tensor::scalar_i32((5 + l as i32) % 32),
                    Tensor::scalar_i32(pos),
                ]
            })
            .collect();
        let items: Vec<BatchItem<'_>> = kvs
            .iter()
            .zip(&inputs)
            .map(|(kv, inp)| BatchItem { kv, inputs: inp })
            .collect();
        let outs = art.call_batched(&items).expect("batched call");
        for (kv, out) in kvs.iter_mut().zip(outs) {
            *kv = out.kv;
        }
    }
    Run { calls: iters, lanes, wall_s: t0.elapsed().as_secs_f64() }
}

/// Drive `rounds` rounds of `groups` *independent* batched calls
/// (separate KV groups, `lanes` lanes each) through one artifact.
/// Serial mode waits out each call before issuing the next — one full
/// round trip per chunk, the protocol-v2 discipline. Pipelined mode
/// submits every group's call first and drains the completion handles
/// after, so up to `groups` calls share the connection's in-flight
/// window. Returns total wall seconds plus every lane's final logits
/// (for the bitwise cross-check).
fn drive_groups(
    rt: &Runtime,
    artifact: &str,
    groups: usize,
    lanes: usize,
    rounds: usize,
    pipelined: bool,
) -> (f64, Vec<Tensor>) {
    let art = rt.artifact(artifact).expect("artifact");
    let max_seq = rt.manifest.model_usize("max_seq").expect("max_seq");
    let k_spec = rt.manifest.spec_usize("k_spec").expect("k_spec");
    let mut kvs: Vec<Vec<Vec<Buffer>>> = (0..groups)
        .map(|_| {
            (0..lanes).map(|_| rt.fresh_kv(artifact).expect("fresh kv")).collect()
        })
        .collect();
    let mut finals: Vec<Tensor> = Vec::new();
    let t0 = Instant::now();
    for round in 0..rounds {
        let pos = (round % (max_seq.saturating_sub(k_spec + 1))) as i32;
        let inputs: Vec<Vec<Vec<Tensor>>> = (0..groups)
            .map(|g| {
                (0..lanes)
                    .map(|l| {
                        vec![
                            Tensor::scalar_i32((3 + g as i32 * 7 + l as i32) % 32),
                            Tensor::scalar_i32(pos),
                        ]
                    })
                    .collect()
            })
            .collect();
        let last = round + 1 == rounds;
        if pipelined {
            let handles: Vec<_> = (0..groups)
                .map(|g| {
                    let items: Vec<BatchItem<'_>> = kvs[g]
                        .iter()
                        .zip(&inputs[g])
                        .map(|(kv, inp)| BatchItem { kv, inputs: inp })
                        .collect();
                    art.call_batched_submit(&items)
                })
                .collect();
            for (g, handle) in handles.into_iter().enumerate() {
                for (kv, out) in kvs[g].iter_mut().zip(handle.wait()) {
                    let out = out.expect("pipelined lane failed");
                    if last {
                        finals.push(out.outputs[0].clone());
                    }
                    *kv = out.kv;
                }
            }
        } else {
            for g in 0..groups {
                let items: Vec<BatchItem<'_>> = kvs[g]
                    .iter()
                    .zip(&inputs[g])
                    .map(|(kv, inp)| BatchItem { kv, inputs: inp })
                    .collect();
                let outs = art.call_batched(&items).expect("serial call failed");
                drop(items);
                for (kv, out) in kvs[g].iter_mut().zip(outs) {
                    if last {
                        finals.push(out.outputs[0].clone());
                    }
                    *kv = out.kv;
                }
            }
        }
    }
    (t0.elapsed().as_secs_f64(), finals)
}

/// Bitwise sanity: the first batched call must agree exactly between
/// the two runtimes before any timing is trusted.
fn parity_check(local: &Runtime, remote: &Runtime, artifact: &str) {
    let inputs = [Tensor::scalar_i32(7), Tensor::scalar_i32(0)];
    let a = local
        .artifact(artifact)
        .unwrap()
        .call(&local.fresh_kv(artifact).unwrap(), &inputs)
        .unwrap();
    let b = remote
        .artifact(artifact)
        .unwrap()
        .call(&remote.fresh_kv(artifact).unwrap(), &inputs)
        .unwrap();
    assert_eq!(
        a.outputs[0], b.outputs[0],
        "local vs remote parity broken for {artifact}"
    );
}

fn main() {
    let tiny = std::env::var("DVI_BENCH_TINY").is_ok();
    let lanes = env_usize("DVI_BENCH_LANES", 8);
    let iters = env_usize("DVI_BENCH_ITERS", if tiny { 20 } else { 200 });

    let local = Arc::new(Runtime::load_reference(SEED).expect("local runtime"));
    let remote =
        Arc::new(Runtime::load_remote_loopback(SEED).expect("remote runtime"));
    parity_check(&local, &remote, "target_step");

    println!(
        "\n== Remote executor overhead: local vs loopback-remote \
         call_batched, lanes={lanes}, iters={iters} =="
    );
    println!();
    println!("| backend | artifact | lanes | calls | wall ms | us/call | us/lane-step |");
    println!("|---|---|---|---|---|---|---|");
    let mut artifact_rows: Vec<String> = Vec::new();
    for artifact in ["target_step", "draft_step"] {
        let l = drive(&local, artifact, lanes, iters);
        let r = drive(&remote, artifact, lanes, iters);
        for (name, s) in [("local", &l), ("remote", &r)] {
            println!(
                "| {name} | {artifact} | {} | {} | {:.2} | {:.1} | {:.2} |",
                s.lanes,
                s.calls,
                s.wall_s * 1e3,
                s.us_per_call(),
                s.us_per_lane_step()
            );
        }
        println!(
            "[remote_overhead] {artifact}: {:.1} us/call added by the wire \
             ({:.2}x local)",
            r.us_per_call() - l.us_per_call(),
            r.us_per_call() / l.us_per_call().max(1e-9)
        );
        artifact_rows.push(format!(
            "{{\"artifact\":\"{artifact}\",\"lanes\":{lanes},\
             \"calls\":{iters},\"local_us_per_call\":{:.2},\
             \"remote_us_per_call\":{:.2},\"overhead_us_per_call\":{:.2}}}",
            l.us_per_call(),
            r.us_per_call(),
            r.us_per_call() - l.us_per_call()
        ));
    }

    // --- serial vs pipelined: same call set, one connection -------------
    let groups = env_usize("DVI_BENCH_GROUPS", 6);
    let rounds = env_usize("DVI_BENCH_ITERS", if tiny { 20 } else { 200 });
    let pl_lanes = (lanes / 2).max(1);
    let serial_rt =
        Runtime::load_remote_loopback_windowed(SEED, 1).expect("serial runtime");
    let piped_rt = Runtime::load_remote_loopback_windowed(SEED, groups.max(2))
        .expect("pipelined runtime");
    println!(
        "\n== Pipelined mux (protocol v3): serial (window 1) vs pipelined \
         (window {}) — {groups} independent chunks x {rounds} rounds, \
         {pl_lanes} lanes each ==",
        groups.max(2)
    );
    println!();
    println!("| discipline | window | chunks | rounds | wall ms | us/chunk-call |");
    println!("|---|---|---|---|---|---|");
    let (serial_s, serial_out) =
        drive_groups(&serial_rt, "target_step", groups, pl_lanes, rounds, false);
    let (piped_s, piped_out) =
        drive_groups(&piped_rt, "target_step", groups, pl_lanes, rounds, true);
    assert_eq!(
        serial_out, piped_out,
        "pipelined outputs diverged from serial — losslessness broken"
    );
    let calls = (groups * rounds) as f64;
    for (name, window, s) in [
        ("serial", 1, serial_s),
        ("pipelined", groups.max(2), piped_s),
    ] {
        println!(
            "| {name} | {window} | {groups} | {rounds} | {:.2} | {:.1} |",
            s * 1e3,
            s * 1e6 / calls
        );
    }
    println!(
        "[remote_overhead] pipelining: {:.2}x serial wall time \
         ({:.1}% saved) over the same {} calls — window > 1 overlaps \
         independent chunks on one connection",
        piped_s / serial_s.max(1e-9),
        (1.0 - piped_s / serial_s.max(1e-9)) * 100.0,
        groups * rounds
    );
    let m = piped_rt
        .executor_status()
        .first()
        .and_then(|s| s.metrics)
        .expect("pipelined executor metrics");
    println!(
        "[remote_overhead] realized window depth: max_inflight={} \
         (window {})",
        m.max_inflight,
        groups.max(2)
    );

    // Machine-readable artifact for CI trend tracking.
    let json = format!(
        "{{\"schema\":\"dvi.bench/1\",\
         \"bench\":\"remote_overhead\",\
         \"artifacts\":[{}],\
         \"pipelining\":{{\"window\":{},\"chunks\":{groups},\
         \"rounds\":{rounds},\"serial_wall_s\":{serial_s:.6},\
         \"piped_wall_s\":{piped_s:.6},\"speedup\":{:.4},\
         \"max_inflight\":{}}}}}",
        artifact_rows.join(","),
        groups.max(2),
        serial_s / piped_s.max(1e-9),
        m.max_inflight
    );
    let path = "BENCH_remote_overhead.json";
    std::fs::write(path, format!("{json}\n")).expect("write bench artifact");
    println!("[remote_overhead] wrote {path}");
}
