//! Shard scaling: the batched scheduler driving a loopback executor
//! fleet of 1 vs 2 vs 4 shards at a fixed offered load, so the cost of
//! the sharded client (routing, per-shard sub-call threads, reassembly)
//! and the benefit of fanning lanes out are both visible before any
//! real network is involved. An in-process reference row anchors the
//! remote overhead.
//!
//! Every configuration's committed token streams are checked bitwise
//! against the 1-shard run before its timing is trusted — sharding is a
//! deployment choice, never a semantic one.
//!
//!   cargo bench --bench shard_scaling
//!
//! Knobs: DVI_BENCH_SEQS   sequences at fixed load   (default 24)
//!        DVI_BENCH_TINY=1 CI smoke scale (8 sequences, shards 1/2)

use std::sync::Arc;
use std::time::Instant;

use dvi::runtime::Runtime;
use dvi::sched::{SchedConfig, Scheduler};

const SEED: u64 = 0x54A2D;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

struct Run {
    label: String,
    wall_s: f64,
    tokens: u64,
    occupancy: f64,
    streams: Vec<Vec<u32>>,
}

/// Drive `cases` through a fresh batched scheduler on `rt`; returns the
/// timing plus the committed streams (submission order) for the
/// losslessness cross-check.
fn drive(
    rt: Arc<Runtime>,
    label: &str,
    cases: &[(Vec<u32>, usize)],
) -> Run {
    let cfg = SchedConfig {
        method: "dvi".into(),
        max_batch: 8,
        max_slots: 16,
        adaptive: None,
        cache: None,
    };
    let mut sched = Scheduler::new(rt, cfg, None).expect("scheduler");
    let t0 = Instant::now();
    let ids: Vec<u64> = cases
        .iter()
        .map(|(p, n)| sched.submit(p.clone(), *n))
        .collect();
    sched.run_until_idle(1_000_000).expect("scheduler drained");
    let wall_s = t0.elapsed().as_secs_f64();
    let mut done = sched.drain_completed();
    assert_eq!(done.len(), cases.len(), "{label}: sequences went missing");
    done.sort_by_key(|r| r.id);
    let streams: Vec<Vec<u32>> = ids
        .iter()
        .zip(done)
        .map(|(&id, r)| {
            assert_eq!(id, r.id);
            r.result.expect("generation failed").tokens
        })
        .collect();
    let tokens = streams.iter().map(|s| s.len() as u64).sum();
    Run {
        label: label.to_string(),
        wall_s,
        tokens,
        occupancy: sched.stats.occupancy(),
        streams,
    }
}

fn main() {
    let tiny = std::env::var("DVI_BENCH_TINY").is_ok();
    let seqs = env_usize("DVI_BENCH_SEQS", if tiny { 8 } else { 24 });
    let shard_counts: &[usize] = if tiny { &[1, 2] } else { &[1, 2, 4] };

    let local = Arc::new(Runtime::load_reference(SEED).expect("local runtime"));
    let cases: Vec<(Vec<u32>, usize)> = {
        let stream = dvi::harness::load_prompts(&local, "stream").expect("prompts");
        stream
            .shuffled(0x5EED)
            .take(seqs)
            .samples
            .iter()
            .map(|s| (s.prompt.clone(), s.max_new.min(16)))
            .collect()
    };

    println!(
        "\n== Shard scaling: batched DVI scheduler over a loopback executor \
         fleet, load={} seqs, max_batch=8, slots=16 ==",
        cases.len()
    );
    println!();
    println!("| backend | shards | wall ms | tokens | tok/s | occupancy |");
    println!("|---|---|---|---|---|---|");

    let mut runs = vec![drive(local.clone(), "in-process", &cases)];
    for &n in shard_counts {
        let rt = Runtime::load_remote_sharded_loopback(SEED, n)
            .expect("sharded loopback runtime");
        runs.push(drive(Arc::new(rt), &format!("sharded x{n}"), &cases));
    }

    // Bitwise losslessness across every configuration before timing is
    // reported: shard count must never change a committed stream.
    for r in &runs[1..] {
        assert_eq!(
            r.streams, runs[0].streams,
            "{}: committed streams diverged from in-process run",
            r.label
        );
    }

    for r in &runs {
        let shards = r.label.strip_prefix("sharded x").unwrap_or("-");
        println!(
            "| {} | {} | {:.2} | {} | {:.0} | {:.2} |",
            r.label,
            shards,
            r.wall_s * 1e3,
            r.tokens,
            r.tokens as f64 / r.wall_s.max(1e-9),
            r.occupancy
        );
    }
    let base = &runs[1]; // sharded x1: the wire baseline
    for r in &runs[2..] {
        println!(
            "[shard_scaling] {} vs x1: {:.2}x wall ({:.1} ms -> {:.1} ms)",
            r.label,
            base.wall_s / r.wall_s.max(1e-9),
            base.wall_s * 1e3,
            r.wall_s * 1e3
        );
    }

    // Machine-readable artifact for CI trend tracking.
    let rows = runs
        .iter()
        .map(|r| {
            format!(
                "{{\"label\":\"{}\",\"wall_s\":{:.6},\"tokens\":{},\
                 \"tok_per_sec\":{:.1},\"occupancy\":{:.4}}}",
                r.label,
                r.wall_s,
                r.tokens,
                r.tokens as f64 / r.wall_s.max(1e-9),
                r.occupancy
            )
        })
        .collect::<Vec<_>>()
        .join(",");
    let json = format!(
        "{{\"schema\":\"dvi.bench/1\",\
         \"bench\":\"shard_scaling\",\"seqs\":{},\"runs\":[{rows}]}}",
        cases.len()
    );
    let path = "BENCH_shard_scaling.json";
    std::fs::write(path, format!("{json}\n")).expect("write bench artifact");
    println!("[shard_scaling] wrote {path}");
}
