//! Table 3 + Figure 2 regenerator: objective ablations (KL-only /
//! PG-only / CE-only), each trained online from a fresh LoRA and then
//! evaluated on the Spec-Bench grid; learning curves dumped as CSV.
//!
//!   cargo bench --bench table3_ablations
//!
//! Knobs: DVI_BENCH_TRAIN (default 400), DVI_BENCH_N (default 15),
//!        DVI_BENCH_OUT (curve dir, default results/).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use dvi::harness;
use dvi::learner::Objective;
use dvi::runtime::Runtime;
use dvi::util::plot::ascii_plot;

fn artifacts_dir() -> PathBuf {
    std::env::var("DVI_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

fn main() {
    let dir = artifacts_dir();
    let train: usize = std::env::var("DVI_BENCH_TRAIN")
        .ok().and_then(|s| s.parse().ok()).unwrap_or(150);
    let n: usize = std::env::var("DVI_BENCH_N")
        .ok().and_then(|s| s.parse().ok()).unwrap_or(5);
    let out_dir = PathBuf::from(
        std::env::var("DVI_BENCH_OUT").unwrap_or_else(|_| "results".into()));
    std::fs::create_dir_all(&out_dir).unwrap();

    let rt = Arc::new(Runtime::load_auto(&dir).unwrap());
    let objectives = [Objective::KlOnly, Objective::PgOnly, Objective::CeOnly,
                      Objective::Dvi];
    let results = harness::ablations(rt, &objectives, train, n).unwrap();

    println!("\n== Table 3 (objective ablations; train={train}, n={n}) ==\n");
    println!("{}", harness::table3_markdown(&results));

    for r in &results {
        let path = out_dir.join(format!("fig2_{}.csv", r.objective.name()));
        let mut csv = String::from("step,batch_accept\n");
        for (s, a) in &r.curve {
            csv.push_str(&format!("{s},{a:.5}\n"));
        }
        std::fs::write(&path, csv).unwrap();
        println!("{}", ascii_plot(
            &format!("Fig 2 [{}]", r.objective.name()),
            &[("batch accept", &r.curve)], 70, 10));
    }
}
