//! Table 1 regenerator: training-budget comparison. Ours are measured
//! (exposures.json written by distill.py + the online run's prompt
//! count); the paper's numbers are shown alongside for reference.
//!
//!   cargo bench --bench table1_budget

use std::path::{Path, PathBuf};

use dvi::harness;
use dvi::runtime::Runtime;

fn artifacts_dir() -> PathBuf {
    std::env::var("DVI_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

fn main() {
    let dir = artifacts_dir();
    let rt = Runtime::load_auto(&dir).unwrap();
    let prompts: usize = std::env::var("DVI_BENCH_TRAIN")
        .ok().and_then(|s| s.parse().ok()).unwrap_or(2000);
    println!("\n== Table 1 (training budgets) ==\n");
    println!("{}", harness::table1(&rt, prompts));
}
