//! Table 4 (serving): per-thread router vs continuous-batching scheduler
//! at several offered loads, equal worker budget. The per-thread router
//! dedicates one OS thread + one batch-size-1 call stream per request;
//! the batched router multiplexes every request through one scheduler
//! thread issuing lane-blocked batched backend calls, so weight
//! streaming amortizes across resident sequences.
//!
//!   cargo bench --bench table4_serving
//!
//! Knobs: DVI_BENCH_LOADS   offered loads, comma list (default 4,8,16)
//!        DVI_BENCH_WORKERS per-thread worker budget   (default 1)
//!        DVI_BENCH_MAX_BATCH  lanes per batched call  (default 8)
//!        DVI_BENCH_METHOD  dvi | ar                   (default dvi)
//!        DVI_BENCH_TINY=1  CI smoke scale (default model, tiny load)

use std::sync::Arc;
use std::time::Instant;

use dvi::harness::load_prompts;
use dvi::learner::Objective;
use dvi::runtime::{ReferenceConfig, Runtime};
use dvi::sched::AdaptiveK;
use dvi::server::{Router, RouterConfig};

struct RunStats {
    tokens: u64,
    wall_s: f64,
    occupancy: f64,
    queue_wait_ms: f64,
    committed_per_tick: f64,
    k_hist: [u64; 9],
    mean_accept_ema: f64,
}

impl RunStats {
    fn tok_per_sec(&self) -> f64 {
        self.tokens as f64 / self.wall_s.max(1e-9)
    }
}

/// Serve one closed batch of requests through a router, wall-clocked
/// from first submit to last response.
fn run_mode(
    rt: Arc<Runtime>,
    cfg: RouterConfig,
    reqs: &[(Vec<u32>, usize)],
) -> RunStats {
    let router = Router::start(rt, cfg).expect("router start");
    let t0 = Instant::now();
    let receivers: Vec<_> = reqs
        .iter()
        .map(|(p, n)| router.submit(p.clone(), *n))
        .collect();
    let mut tokens = 0u64;
    for rx in receivers {
        tokens += rx.recv().expect("response").tokens.len() as u64;
    }
    let wall_s = t0.elapsed().as_secs_f64();
    let (occupancy, queue_wait_ms, committed_per_tick, k_hist, mean_accept_ema) =
        match &router.sched_stats {
            Some(s) => (
                s.occupancy(),
                s.mean_queue_wait_ms(),
                s.committed_per_tick(),
                s.k_hist_snapshot(),
                s.mean_accept_ema(),
            ),
            None => (1.0, 0.0, 0.0, [0u64; 9], 0.0),
        };
    router.shutdown();
    RunStats {
        tokens,
        wall_s,
        occupancy,
        queue_wait_ms,
        committed_per_tick,
        k_hist,
        mean_accept_ema,
    }
}

fn json_run(s: &RunStats) -> String {
    let hist = s
        .k_hist
        .iter()
        .map(|c| c.to_string())
        .collect::<Vec<_>>()
        .join(",");
    format!(
        "{{\"tokens\":{},\"wall_s\":{:.6},\"tok_per_sec\":{:.2},\
         \"occupancy\":{:.3},\"tok_per_tick\":{:.3},\"k_hist\":[{hist}],\
         \"mean_accept_ema\":{:.4}}}",
        s.tokens, s.wall_s, s.tok_per_sec(), s.occupancy,
        s.committed_per_tick, s.mean_accept_ema
    )
}

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn main() {
    let tiny = std::env::var("DVI_BENCH_TINY").is_ok();
    let loads_env = std::env::var("DVI_BENCH_LOADS").unwrap_or_else(|_| {
        if tiny { "4".to_string() } else { "4,8,16".to_string() }
    });
    let loads: Vec<usize> = loads_env
        .split(',')
        .filter_map(|s| s.trim().parse().ok())
        .collect();
    let workers = env_usize("DVI_BENCH_WORKERS", 1);
    let max_batch = env_usize("DVI_BENCH_MAX_BATCH", 8);
    let method =
        std::env::var("DVI_BENCH_METHOD").unwrap_or_else(|_| "dvi".to_string());

    // Serving-scale geometry: large enough that per-call weight
    // streaming dominates, which is what lane-blocked batched execution
    // amortizes. Tiny (CI smoke) keeps the default test-scale model and
    // just exercises the full path.
    let ref_cfg = if tiny {
        ReferenceConfig::default()
    } else {
        // ~2 MB of weights: larger than a typical per-core L2, so the
        // per-sequence path re-streams every layer from L3 on every
        // call while the batched path reuses each layer across lanes.
        ReferenceConfig {
            vocab_size: 256,
            d_model: 96,
            d_ff: 192,
            n_layers: 6,
            split_layer: 2,
            max_seq: 192,
            prefill_seq: 48,
            max_new_tokens: 40,
            ..ReferenceConfig::default()
        }
    };
    let rt = Arc::new(Runtime::load_reference_with(ref_cfg).unwrap());

    // Mixed-task offered load: the online stream, deterministically
    // shuffled (PromptSet::shuffled), with per-request budget variety so
    // completion times are heterogeneous like live traffic.
    let stream = load_prompts(&rt, "stream").unwrap().shuffled(0x7AB1E4);

    println!(
        "\n== Table 4 (serving): per-thread vs batched, method={method}, \
         worker budget={workers}, max_batch={max_batch} =="
    );
    println!();
    println!(
        "| mode | load | tokens | wall s | tok/s | occupancy | \
         queue wait ms | tok/tick |"
    );
    println!("|---|---|---|---|---|---|---|---|");
    let mut speedups = Vec::new();
    for &load in &loads {
        let reqs: Vec<(Vec<u32>, usize)> = stream
            .samples
            .iter()
            .take(load)
            .enumerate()
            .map(|(i, s)| (s.prompt.clone(), s.max_new.min(16 + (i % 3) * 12)))
            .collect();
        let per_thread = run_mode(
            rt.clone(),
            RouterConfig {
                workers,
                method: method.clone(),
                online: false,
                objective: Objective::Dvi,
                buffer_capacity: 4096,
                ..RouterConfig::default()
            },
            &reqs,
        );
        let batched = run_mode(
            rt.clone(),
            RouterConfig {
                method: method.clone(),
                online: false,
                objective: Objective::Dvi,
                buffer_capacity: 4096,
                batched: true,
                max_batch,
                max_slots: load.max(1),
                ..RouterConfig::default()
            },
            &reqs,
        );
        for (name, s) in [("threads", &per_thread), ("batched", &batched)] {
            println!(
                "| {name} | {load} | {} | {:.3} | {:.0} | {:.2} | {:.2} | {:.2} |",
                s.tokens,
                s.wall_s,
                s.tok_per_sec(),
                s.occupancy,
                s.queue_wait_ms,
                s.committed_per_tick
            );
        }
        speedups.push((load, batched.tok_per_sec() / per_thread.tok_per_sec().max(1e-9), batched.occupancy));
    }
    println!();
    for (load, speedup, occ) in &speedups {
        println!(
            "[table4] load {load}: batched/per-thread throughput {speedup:.2}x, \
             mean batch occupancy {occ:.2}"
        );
    }

    // ---- fixed-k vs adaptive-k on the mixed stream load ----------------
    // Same batched scheduler, same requests; only the speculation-depth
    // policy differs. Committed streams are identical either way (greedy
    // longest-prefix acceptance); the question is committed tokens/sec
    // when low-acceptance sequences stop paying for full-depth rounds.
    if method == "dvi" {
        let load = loads.iter().copied().max().unwrap_or(4);
        let reqs: Vec<(Vec<u32>, usize)> = stream
            .samples
            .iter()
            .take(load)
            .enumerate()
            .map(|(i, s)| (s.prompt.clone(), s.max_new.min(16 + (i % 3) * 12)))
            .collect();
        let batched_cfg = |adaptive: Option<AdaptiveK>| RouterConfig {
            method: method.clone(),
            online: false,
            objective: Objective::Dvi,
            buffer_capacity: 4096,
            batched: true,
            max_batch,
            max_slots: load.max(1),
            adaptive,
            ..RouterConfig::default()
        };
        let fixed = run_mode(rt.clone(), batched_cfg(None), &reqs);
        let adaptive =
            run_mode(rt.clone(), batched_cfg(Some(AdaptiveK::default())), &reqs);
        let ratio = adaptive.tok_per_sec() / fixed.tok_per_sec().max(1e-9);
        println!();
        println!(
            "| batched fixed-k | {load} | {} | {:.3} | {:.0} | {:.2} | {:.2} | {:.2} |",
            fixed.tokens, fixed.wall_s, fixed.tok_per_sec(),
            fixed.occupancy, fixed.queue_wait_ms, fixed.committed_per_tick
        );
        println!(
            "| batched adaptive-k | {load} | {} | {:.3} | {:.0} | {:.2} | {:.2} | {:.2} |",
            adaptive.tokens, adaptive.wall_s, adaptive.tok_per_sec(),
            adaptive.occupancy, adaptive.queue_wait_ms,
            adaptive.committed_per_tick
        );
        println!(
            "[table4] load {load}: adaptive-k/fixed-k committed tok/s {ratio:.2}x \
             (mean acceptance EMA {:.2}, chosen-k hist {:?})",
            adaptive.mean_accept_ema, adaptive.k_hist
        );
        assert_eq!(
            adaptive.tokens, fixed.tokens,
            "adaptive-k changed the number of committed tokens"
        );

        // Machine-readable artifact for CI trend tracking.
        let json = format!(
            "{{\"schema\":\"dvi.bench/1\",\
             \"bench\":\"table4_serving\",\"method\":\"{method}\",\
             \"load\":{load},\"workers\":{workers},\"max_batch\":{max_batch},\
             \"fixed_k\":{},\"adaptive_k\":{},\
             \"adaptive_over_fixed\":{ratio:.4}}}",
            json_run(&fixed), json_run(&adaptive)
        );
        let path = "BENCH_serving.json";
        std::fs::write(path, format!("{json}\n")).expect("write bench artifact");
        println!("[table4] wrote {path}");
    }
}
