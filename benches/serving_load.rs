//! Open-loop multi-tenant serving load: a seeded workload schedule
//! (Poisson and bursty arrivals over per-tenant task mixes — see
//! `dvi::workload::gen`) drives the batched scheduler on the in-process
//! reference backend, a loopback executor, and a 2-shard loopback
//! fleet. Requests are admitted at their scheduled wall-clock arrival
//! via `submit_with_deadline`, so queue-wait and TTFT include time
//! spent in the admission queue — the part a closed-loop driver can't
//! see — and each admission's tenant deadline rides into the
//! scheduler's health ledger.
//!
//! Reports per-request queue-wait / TTFT / end-to-end latency
//! (p50/p95/p99), goodput (committed tokens/s), **SLO goodput** (tokens
//! from in-deadline completions only — the chat tenant carries a
//! per-request latency deadline, the batch tenant is best-effort),
//! acceptance EMA, and — with `DVI_PREFIX_CACHE=1` — cache hit rate,
//! per tenant and overall, and persists a schema-versioned
//! `BENCH_serving_load.json` for the `dvi bench-compare` trajectory
//! gate.
//!
//!   cargo bench --bench serving_load
//!
//! Knobs: DVI_BENCH_REQS       requests per scenario (default 96)
//!        DVI_BENCH_RATE       mean poisson arrival rate, req/s (150)
//!        DVI_BENCH_SEED       workload seed            (default 0x10AD)
//!        DVI_BENCH_MAX_BATCH  scheduler max_batch      (default 8)
//!        DVI_BENCH_SLOTS     scheduler slot pool       (default 16)
//!        DVI_BENCH_METHOD    sequence engine           (default dvi)
//!        DVI_BENCH_SLO_MS    chat tenant's deadline, ms (default 500)
//!        DVI_BENCH_TINY=1    CI smoke: 16 requests, 300 req/s,
//!                            in-process + loopback only

use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

use dvi::metrics::bench::SCHEMA;
use dvi::obs::metrics::Registry;
use dvi::runtime::Runtime;
use dvi::sched::{CacheConfig, SchedConfig, Scheduler};
use dvi::util::json::{self, Json};
use dvi::workload::gen::{
    encode_schedule, fingerprint, generate, Admission, Arrival, LenDist,
    TenantSpec, WorkloadSpec,
};
use dvi::workload::TASK_NAMES;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn env_f64(key: &str, default: f64) -> f64 {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// Two tenants with deliberately different task mixes and shapes:
/// acceptance — hence speedup — is task-dependent, so a uniform stream
/// would hide exactly the contention this bench exists to measure.
fn tenants() -> Vec<TenantSpec> {
    vec![
        TenantSpec {
            name: "chat".into(),
            weight: 0.7,
            task_mix: vec![("qa".into(), 0.6), ("mt".into(), 0.4)],
            prompt_len: LenDist::Uniform { lo: 6, hi: 16 },
            max_new: LenDist::Uniform { lo: 4, hi: 10 },
            // Interactive tenant: every request carries a latency
            // deadline, so queueing collapse shows up as lost SLO
            // goodput even while raw goodput looks healthy.
            slo_ms: Some(env_usize("DVI_BENCH_SLO_MS", 500) as u64),
        },
        TenantSpec {
            name: "batch".into(),
            weight: 0.3,
            task_mix: vec![
                ("summarization".into(), 0.5),
                ("rag".into(), 0.3),
                ("translation".into(), 0.2),
            ],
            prompt_len: LenDist::Uniform { lo: 10, hi: 24 },
            max_new: LenDist::Uniform { lo: 8, hi: 16 },
            // Throughput tenant: best-effort, no deadline.
            slo_ms: None,
        },
    ]
}

/// p50/p95/p99 (milliseconds) of a nanosecond histogram; zeros when
/// the histogram saw no samples (a tenant with no completed requests).
fn quantiles_ms(reg: &Registry, name: &str) -> Json {
    let snap = reg.hist(name).snapshot();
    let q = |p: f64| -> Json {
        if snap.count == 0 {
            json::num(0.0)
        } else {
            json::num(snap.quantile(p) as f64 / 1e6)
        }
    };
    json::obj(vec![("p50", q(0.50)), ("p95", q(0.95)), ("p99", q(0.99))])
}

struct Done {
    tenant: u32,
    tokens: u64,
    /// Completed within its admission's deadline (always true for
    /// best-effort requests) — the SLO-goodput filter.
    met: bool,
}

/// Replay `schedule` open-loop against a fresh scheduler on `rt`:
/// requests are admitted when their arrival timestamp passes, stamped
/// with that arrival, regardless of whether the scheduler has kept up.
/// Returns the scenario's artifact object.
fn drive(
    rt: Arc<Runtime>,
    arrival: &str,
    backend: &str,
    schedule: &[Admission],
    tenant_names: &[String],
) -> Json {
    let cfg = SchedConfig {
        method: std::env::var("DVI_BENCH_METHOD")
            .unwrap_or_else(|_| "dvi".into()),
        max_batch: env_usize("DVI_BENCH_MAX_BATCH", 8),
        max_slots: env_usize("DVI_BENCH_SLOTS", 16),
        adaptive: None,
        cache: CacheConfig::from_env(),
    };
    let label = format!("{arrival}/{backend}");
    let mut sched = Scheduler::new(rt, cfg, None).expect("scheduler");
    let reg = Registry::new();
    let mut recs: Vec<Option<Done>> =
        (0..schedule.len()).map(|_| None).collect();
    let epoch = Instant::now();
    let mut next = 0usize;
    let mut guard = 0u64;
    while next < schedule.len() || !sched.is_idle() {
        guard += 1;
        assert!(guard < 50_000_000, "{label}: driver wedged");
        let now_ns = epoch.elapsed().as_nanos() as u64;
        while next < schedule.len() && schedule[next].at_ns <= now_ns {
            let a = &schedule[next];
            let id = sched.submit_with_deadline(
                a.prompt.clone(),
                a.max_new,
                Some(TASK_NAMES[a.task as usize]),
                epoch + Duration::from_nanos(a.at_ns),
                a.deadline_ns,
            );
            assert_eq!(
                id as usize, next,
                "{label}: scheduler ids must track submission order"
            );
            next += 1;
        }
        if sched.is_idle() {
            // Nothing resident and nothing due: sleep until the next
            // scheduled arrival (loop invariant: next < len here).
            let due = schedule[next].at_ns;
            let now = epoch.elapsed().as_nanos() as u64;
            if due > now {
                thread::sleep(Duration::from_nanos(due - now));
            }
            continue;
        }
        sched.tick().expect("tick");
        for r in sched.drain_completed() {
            let done_ns = epoch.elapsed().as_nanos() as u64;
            let a = &schedule[r.id as usize];
            let out = r.result.unwrap_or_else(|e| {
                panic!("{label}: sequence {} failed: {e:#}", r.id)
            });
            let e2e_ns = done_ns.saturating_sub(a.at_ns);
            let ttft_ns =
                r.ttft_ns.expect("committed sequence reports a TTFT");
            reg.hist("queue_wait_ns.all").observe(r.queue_wait_ns);
            reg.hist("ttft_ns.all").observe(ttft_ns);
            reg.hist("e2e_ns.all").observe(e2e_ns);
            let tname = &tenant_names[a.tenant as usize];
            reg.hist(&format!("e2e_ns.{tname}")).observe(e2e_ns);
            recs[r.id as usize] = Some(Done {
                tenant: a.tenant,
                tokens: out.tokens.len() as u64,
                met: a.deadline_ns.map_or(true, |d| e2e_ns <= d),
            });
        }
    }
    let wall_s = epoch.elapsed().as_secs_f64().max(1e-9);
    assert!(
        recs.iter().all(|r| r.is_some()),
        "{label}: a scheduled request never completed"
    );

    let total_tokens: u64 = recs.iter().flatten().map(|r| r.tokens).sum();
    let slo_tokens: u64 =
        recs.iter().flatten().filter(|r| r.met).map(|r| r.tokens).sum();
    let tenants_json: Vec<Json> = tenant_names
        .iter()
        .enumerate()
        .map(|(ti, name)| {
            let mine: Vec<&Done> = recs
                .iter()
                .flatten()
                .filter(|r| r.tenant == ti as u32)
                .collect();
            let tokens: u64 = mine.iter().map(|r| r.tokens).sum();
            let in_deadline = mine.iter().filter(|r| r.met).count();
            let slo_tok: u64 =
                mine.iter().filter(|r| r.met).map(|r| r.tokens).sum();
            json::obj(vec![
                ("name", json::s(name)),
                ("requests", json::num(mine.len() as f64)),
                ("tokens", json::num(tokens as f64)),
                ("goodput_tok_per_sec", json::num(tokens as f64 / wall_s)),
                (
                    "slo_attainment",
                    json::num(if mine.is_empty() {
                        1.0
                    } else {
                        in_deadline as f64 / mine.len() as f64
                    }),
                ),
                (
                    "slo_goodput_tok_per_sec",
                    json::num(slo_tok as f64 / wall_s),
                ),
                ("e2e_ms", quantiles_ms(&reg, &format!("e2e_ns.{name}"))),
            ])
        })
        .collect();

    let ema = sched.stats.mean_accept_ema();
    let mut fields = vec![
        ("label", json::s(&label)),
        ("arrival", json::s(arrival)),
        ("backend", json::s(backend)),
        ("requests", json::num(schedule.len() as f64)),
        ("wall_s", json::num(wall_s)),
        (
            "goodput_tok_per_sec",
            json::num(total_tokens as f64 / wall_s),
        ),
        (
            "slo_goodput_tok_per_sec",
            json::num(slo_tokens as f64 / wall_s),
        ),
        (
            "accept_ema",
            json::num(if ema.is_finite() { ema } else { 0.0 }),
        ),
        (
            "latency",
            json::obj(vec![
                ("queue_wait_ms", quantiles_ms(&reg, "queue_wait_ns.all")),
                ("ttft_ms", quantiles_ms(&reg, "ttft_ns.all")),
                ("e2e_ms", quantiles_ms(&reg, "e2e_ns.all")),
            ]),
        ),
        ("tenants", Json::Arr(tenants_json)),
    ];
    if let Some(cs) = sched.cache_stats() {
        let total = (cs.hits + cs.misses).max(1);
        fields.push((
            "cache_hit_rate",
            json::num(cs.hits as f64 / total as f64),
        ));
    }
    let scenario = json::obj(fields);
    println!(
        "| {label} | {} | {:.0} | {:.0} | {:.2} | {:.2} | {:.2} |",
        schedule.len(),
        total_tokens as f64 / wall_s,
        slo_tokens as f64 / wall_s,
        scenario.get("latency").get("e2e_ms").get("p50").as_f64().unwrap(),
        scenario.get("latency").get("e2e_ms").get("p99").as_f64().unwrap(),
        wall_s * 1e3,
    );
    scenario
}

fn main() {
    let tiny = std::env::var("DVI_BENCH_TINY").is_ok();
    let requests = env_usize("DVI_BENCH_REQS", if tiny { 16 } else { 96 });
    let rate = env_f64("DVI_BENCH_RATE", if tiny { 300.0 } else { 150.0 });
    let seed = env_usize("DVI_BENCH_SEED", 0x10AD) as u64;

    let local =
        Arc::new(Runtime::load_reference(0x5EED).expect("local runtime"));
    let source =
        dvi::harness::load_prompts(&local, "stream").expect("stream prompts");
    let tenants = tenants();
    let tenant_names: Vec<String> =
        tenants.iter().map(|t| t.name.clone()).collect();

    // Bursty: on/off phases around the same mean rate — 2.5x the rate
    // inside bursts, a trickle between them.
    let arrivals: Vec<(&str, Arrival)> = vec![
        ("poisson", Arrival::Poisson { rate_per_s: rate }),
        (
            "bursty",
            Arrival::Bursty {
                rate_on: rate * 2.5,
                rate_off: rate * 0.25,
                on_s: 0.12,
                off_s: 0.12,
            },
        ),
    ];

    println!(
        "\n== Open-loop serving load: {} requests/scenario, {} tenants, \
         mean rate {:.0} req/s, seed {seed:#x} ==",
        requests,
        tenants.len(),
        rate
    );
    println!();
    println!(
        "| scenario | reqs | goodput tok/s | slo tok/s | e2e p50 ms | \
         e2e p99 ms | wall ms |"
    );
    println!("|---|---|---|---|---|---|---|");

    let mut schedules: Vec<(&str, Vec<Admission>, u64)> = Vec::new();
    for (name, arrival) in &arrivals {
        let spec = WorkloadSpec {
            seed,
            requests,
            arrival: arrival.clone(),
            tenants: tenants.clone(),
        };
        let schedule = generate(&spec, &source).expect("workload");
        // Replay gate: the same seed must reproduce the admission
        // schedule bitwise before any timing is trusted.
        let replay = generate(&spec, &source).expect("workload replay");
        assert_eq!(
            encode_schedule(&schedule),
            encode_schedule(&replay),
            "{name}: schedule replay diverged for seed {seed:#x}"
        );
        let fp = fingerprint(&schedule);
        schedules.push((name, schedule, fp));
    }

    let backends: &[&str] = if tiny {
        &["in-process", "loopback"]
    } else {
        &["in-process", "loopback", "sharded x2"]
    };
    let mut scenarios: Vec<Json> = Vec::new();
    for (arrival_name, schedule, _) in &schedules {
        for backend in backends {
            let rt = match *backend {
                "in-process" => local.clone(),
                "loopback" => Arc::new(
                    Runtime::load_remote_loopback(0x5EED)
                        .expect("loopback runtime"),
                ),
                _ => Arc::new(
                    Runtime::load_remote_sharded_loopback(0x5EED, 2)
                        .expect("sharded loopback runtime"),
                ),
            };
            scenarios.push(drive(
                rt,
                arrival_name,
                backend,
                schedule,
                &tenant_names,
            ));
        }
    }

    let doc = json::obj(vec![
        ("schema", json::s(SCHEMA)),
        ("bench", json::s("serving_load")),
        ("seed", json::num(seed as f64)),
        ("requests", json::num(requests as f64)),
        ("rate_per_s", json::num(rate)),
        (
            "schedule_fingerprints",
            json::obj(
                schedules
                    .iter()
                    .map(|(n, _, fp)| (*n, json::s(&format!("{fp:016x}"))))
                    .collect(),
            ),
        ),
        ("scenarios", Json::Arr(scenarios)),
    ]);
    let path = "BENCH_serving_load.json";
    std::fs::write(path, format!("{doc}\n")).expect("write bench artifact");
    println!("\n[serving_load] wrote {path}");
}
