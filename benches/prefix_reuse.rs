//! Prefix reuse: cold prefill vs warm (cached-prefix) admission on a
//! shared-system-prompt workload — the radix cache's target shape.
//! Every request carries the same system preamble plus a short unique
//! tail, so a warm scheduler attaches most of each prompt from the tree
//! and recomputes only the tail.
//!
//! Committed streams are cross-checked **bitwise** against the cold
//! (cache-off) run before any timing is trusted — prefix reuse is a
//! performance feature, never a semantic one. The row accounting is
//! deterministic and hard-asserted: a warm pass must prefill strictly
//! fewer positions than cold (cold rows − rows attached from cache).
//!
//!   cargo bench --bench prefix_reuse
//!
//! Knobs: DVI_BENCH_SEQS   sequences per pass (default 24)
//!        DVI_BENCH_TINY=1 CI smoke scale (8 sequences)

use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

use dvi::runtime::Runtime;
use dvi::sched::{CacheConfig, SchedConfig, Scheduler};

const SEED: u64 = 0x9EF1C;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn cfg(cache: bool) -> SchedConfig {
    SchedConfig {
        method: "dvi".into(),
        max_batch: 8,
        max_slots: 16,
        adaptive: None,
        cache: if cache { Some(CacheConfig { capacity: 64 }) } else { None },
    }
}

/// One pass of `cases` through `sched`: wall time + committed streams
/// in submission order.
fn pass(
    sched: &mut Scheduler,
    cases: &[(Vec<u32>, usize)],
) -> (f64, Vec<Vec<u32>>) {
    let t0 = Instant::now();
    let ids: Vec<u64> = cases
        .iter()
        .map(|(p, n)| sched.submit(p.clone(), *n))
        .collect();
    sched.run_until_idle(1_000_000).expect("scheduler drained");
    let wall_s = t0.elapsed().as_secs_f64();
    let mut done = sched.drain_completed();
    assert_eq!(done.len(), cases.len(), "sequences went missing");
    done.sort_by_key(|r| r.id);
    let streams = ids
        .iter()
        .zip(done)
        .map(|(&id, r)| {
            assert_eq!(id, r.id);
            r.result.expect("generation failed").tokens
        })
        .collect();
    (wall_s, streams)
}

fn main() {
    let tiny = std::env::var("DVI_BENCH_TINY").is_ok();
    let seqs = env_usize("DVI_BENCH_SEQS", if tiny { 8 } else { 24 });
    let sys_len = 24usize;

    let rt = Arc::new(Runtime::load_reference(SEED).expect("runtime"));
    let prefill_seq = rt.manifest.spec_usize("prefill_seq").expect("prefill_seq");

    // Shared-system-prompt workload: `sys_len` common tokens, unique tail.
    let cases: Vec<(Vec<u32>, usize)> = {
        let stream = dvi::harness::load_prompts(&rt, "stream").expect("prompts");
        let shuffled = stream.shuffled(0x5EED);
        let sys: Vec<u32> = shuffled.samples[0]
            .prompt
            .iter()
            .cycle()
            .take(sys_len)
            .cloned()
            .collect();
        shuffled
            .samples
            .iter()
            .cycle()
            .take(seqs)
            .enumerate()
            .map(|(i, s)| {
                let mut p = sys.clone();
                // Per-request disambiguator inside the closed synthetic
                // vocabulary (ids 4.. are ordinary words).
                p.push((i % 60) as u32 + 4);
                p.extend(s.prompt.iter().cloned());
                p.truncate(prefill_seq.min(sys_len + 12));
                (p, s.max_new.min(6))
            })
            .collect()
    };

    println!(
        "\n== Prefix reuse: {} seqs sharing a {sys_len}-token system \
         prompt, prefill_seq={prefill_seq} ==\n",
        cases.len()
    );

    // Cold reference: cache off, every admission prefills from scratch.
    let mut cold_sched = Scheduler::new(rt.clone(), cfg(false), None).unwrap();
    let (cold_wall, cold_streams) = pass(&mut cold_sched, &cases);

    // Warm: first pass populates the tree (later admissions already hit
    // earlier donations), second pass is fully warm.
    let mut warm_sched = Scheduler::new(rt.clone(), cfg(true), None).unwrap();
    let (populate_wall, populate_streams) = pass(&mut warm_sched, &cases);
    let rows_pass1 = warm_sched.stats.cache_shared_rows.load(Ordering::Relaxed);
    let (warm_wall, warm_streams) = pass(&mut warm_sched, &cases);
    let shared_rows = warm_sched.stats.cache_shared_rows.load(Ordering::Relaxed);
    let rows_pass2 = shared_rows - rows_pass1;

    // Losslessness first, timing second.
    assert_eq!(
        populate_streams, cold_streams,
        "cache-populating pass diverged from cold streams"
    );
    assert_eq!(
        warm_streams, cold_streams,
        "warm pass diverged from cold streams"
    );

    // Deterministic admission-cost accounting (per prefill stage): a
    // cold pass computes prefill_seq positions per sequence; a warm
    // pass skips every attached row. Strictly fewer, by construction —
    // hard-asserted so a silent cache regression fails the bench.
    let cold_rows = (cases.len() * prefill_seq) as u64;
    let warm_rows = cold_rows - rows_pass2;
    assert!(
        rows_pass2 > 0 && warm_rows < cold_rows,
        "warm pass attached no cached rows (shared={rows_pass2})"
    );
    let cs = warm_sched.cache_stats().expect("cache on");
    assert!(cs.hits >= cases.len() as u64, "second pass was not fully warm");

    println!("| pass | wall ms | prefill rows/stage | shared rows |");
    println!("|---|---|---|---|");
    println!("| cold (cache off) | {:.2} | {cold_rows} | 0 |", cold_wall * 1e3);
    println!(
        "| populate (cache on, empty) | {:.2} | {} | {rows_pass1} |",
        populate_wall * 1e3,
        cold_rows - rows_pass1
    );
    println!(
        "| warm (cache on, resident) | {:.2} | {warm_rows} | {rows_pass2} |",
        warm_wall * 1e3
    );
    println!(
        "[prefix_reuse] warm prefill rows {warm_rows} vs cold {cold_rows} \
         ({:.1}% skipped), wall {:.1} ms -> {:.1} ms",
        100.0 * rows_pass2 as f64 / cold_rows as f64,
        cold_wall * 1e3,
        warm_wall * 1e3
    );

    let json = format!(
        "{{\"schema\":\"dvi.bench/1\",\
         \"bench\":\"prefix_reuse\",\"seqs\":{},\"sys_len\":{sys_len},\
         \"prefill_seq\":{prefill_seq},\"cold_wall_s\":{cold_wall:.6},\
         \"populate_wall_s\":{populate_wall:.6},\
         \"warm_wall_s\":{warm_wall:.6},\"cold_prefill_rows\":{cold_rows},\
         \"warm_prefill_rows\":{warm_rows},\"warm_shared_rows\":{rows_pass2},\
         \"cache_hits\":{},\"cache_evictions\":{}}}",
        cases.len(),
        cs.hits,
        cs.evictions
    );
    let path = "BENCH_prefix_reuse.json";
    std::fs::write(path, format!("{json}\n")).expect("write bench artifact");
    println!("[prefix_reuse] wrote {path}");
}
