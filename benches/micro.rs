//! Micro-benchmarks: per-artifact call latency. The L3 perf pass reads
//! these to find the hot path (EXPERIMENTS.md §Perf).
//!
//! Runs on whichever backend `Runtime::load_auto` picks: PJRT when the
//! feature is compiled in and artifacts exist, the pure-Rust reference
//! backend otherwise — so the bench always produces numbers.
//!
//!   cargo bench --bench micro

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use dvi::runtime::{Role, Runtime, Tensor};

fn artifacts_dir() -> PathBuf {
    std::env::var("DVI_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
}

fn bench_artifact(rt: &Arc<Runtime>, name: &str, iters: usize) {
    let art = rt.artifact(name).expect("artifact");
    let spec = art.spec.clone();
    let mut kv: Vec<_> = rt.fresh_kv(name).unwrap();
    let inputs: Vec<Tensor> = spec
        .params_with_role(Role::In)
        .map(|p| match p.dtype {
            dvi::runtime::DType::F32 => {
                if p.name == "hyper" {
                    // A sane hyper vector (KL-only, step 1) so the
                    // train_step bench doesn't poison the LoRA globals.
                    Tensor::f32(
                        p.shape.clone(),
                        vec![0.0, 1.0, 0.0, 0.0, 0.0, 0.0, 3e-3, 1.0],
                    )
                } else {
                    Tensor::zeros_f32(p.shape.clone())
                }
            }
            dvi::runtime::DType::I32 => {
                let n: usize = p.shape.iter().product();
                Tensor::i32(p.shape.clone(), vec![1; n.max(1)][..n].to_vec())
            }
        })
        .collect();

    // warmup (chain kv state only when the artifact takes kv inputs —
    // prefill artifacts *emit* kv without consuming it)
    for _ in 0..3 {
        let out = art.call(&kv, &inputs).unwrap();
        if out.kv.len() == kv.len() {
            kv = out.kv;
        }
    }
    let t0 = Instant::now();
    for _ in 0..iters {
        let out = art.call(&kv, &inputs).unwrap();
        if out.kv.len() == kv.len() {
            kv = out.kv;
        }
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{name:24} {:8.3} ms/call   ({iters} iters)", per * 1e3);
}

fn main() {
    let rt = Arc::new(Runtime::load_auto(&artifacts_dir()).unwrap());
    println!("== per-artifact call latency [{} backend] ==", rt.backend_name());
    let iters = std::env::var("DVI_BENCH_ITERS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(30);
    for name in [
        "draft_step",
        "draft_block",
        "verify_block",
        "target_step",
        "target_verify_block",
        "sps_draft_step",
        "medusa_heads",
        "hydra_chain",
        "eagle_step",
        "train_step",
        "prefill_shallow",
        "prefill_deep",
        "prefill_full",
    ] {
        if rt.has_artifact(name) {
            bench_artifact(&rt, name, iters);
        }
    }
}
